"""Analytic per-cell FLOPs / HBM-bytes model (sharding-aware).

Why analytic: XLA's ``cost_analysis()`` counts while-loop bodies ONCE
(verified: a 10-step scan reports exactly 1/10 the unrolled FLOPs), so for
scan-over-layers + grad-accumulation programs its flops/bytes are meaningless.
We therefore compute exact matmul/attention FLOPs and a first-order HBM
traffic model from the architecture itself, split by component, and divide
each component by the number of devices it actually parallelizes over
(attention stays model-replicated when heads don't divide the TP axis, etc.).
Collective bytes still come from the compiled HLO (loop-corrected walker in
``analysis.py``) and buffer sizes from ``memory_analysis()`` — those are
exact.

Conventions: matmul [m,k]×[k,n] = 2mkn FLOPs; attention = 4·T·Sk·H·dh
(scores + PV); training = fwd × (4 with remat: fwd + recompute + 2·bwd);
HBM bytes: every weight read once per traversal, activations c·T·D per layer,
attention score tensors counted (the jnp path spills them — the flash-kernel
hillclimb attacks exactly this term).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class Component:
    name: str
    flops: float          # global per step
    bytes: float          # global HBM traffic per step
    parallel: int         # devices this component divides over

    def per_device(self) -> Tuple[float, float]:
        return self.flops / self.parallel, self.bytes / self.parallel


def _dtype_bytes(cfg: ModelConfig) -> int:
    return 2 if cfg.dtype == "bfloat16" else 4


def components(
    cfg: ModelConfig,
    shape: ShapeConfig,
    *,
    dp: int,                  # data-parallel ways (incl. pod)
    tp: int,                  # model-parallel ways
    retention: float = 0.5,
    microbatches: int = 8,
    remat: bool = True,
    q_chunk: int = 1024,
    flash_refresh: bool = False,
) -> List[Component]:
    B, S = shape.global_batch, shape.seq_len
    db = _dtype_bytes(cfg)
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    Lh = cfg.n_layers
    kind = shape.kind
    train = kind == "train"
    # tokens processed by the backbone this step
    if kind in ("train", "prefill"):
        T = B * S
        Sk = S
    else:
        T = B * 1          # decode: one-token active block
        Sk = int(S * retention) + 1
    # attention TP degree: head-sharded when divisible; the flash-refresh
    # kernel falls back to query-sequence sharding over the model axis, so
    # it always engages the full TP degree (§Perf iteration C2)
    h_par = tp if (H and H % tp == 0) or (flash_refresh and not train) else 1
    w_par = tp                                       # weight-sharded matmuls
    fwd_mult = (4.0 if remat else 3.0) if train else 1.0
    mem_mult = 3.0 if train else 1.0                 # fwd+bwd traversals

    comps: List[Component] = []

    def add(name, flops, byts, par):
        comps.append(Component(name, flops * fwd_mult, byts * mem_mult,
                               max(par, 1)))

    if cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
        n_attn = Lh if cfg.family != "hybrid" else Lh // cfg.shared_attn_interval
        qkv_f = 2 * T * D * (H + 2 * K) * dh * n_attn
        wqkv_b = D * (H + 2 * K) * dh * db * n_attn * (microbatches if train else 1)
        add("qkv_proj", qkv_f, wqkv_b + 4 * T * D * db * n_attn, dp * w_par)
        # attention: local layers see a bounded window
        if cfg.layer_pattern == "alt_local_global" and cfg.sliding_window:
            sk_eff = (min(Sk, 2 * cfg.sliding_window + 1) + Sk) / 2
        else:
            sk_eff = Sk
        attn_f = 4 * T * sk_eff * H * dh * n_attn
        if flash_refresh and not train:
            # Pallas flash kernel: scores/probs never leave VMEM. HBM traffic
            # = q/out (2·T·H·dh) + K/V re-streamed once per q-tile pass
            # (T/q_tile passes over Sk·K·dh·2 bytes; q_tile = 256).
            attn_b = (2 * T * H * dh * db * n_attn
                      + (T // 256 + 1) * sk_eff * K * dh * 2 * db * n_attn)
        else:
            # jnp path: f32 scores written + read
            attn_b = (T * sk_eff * H * 4 * 2 + 2 * T * H * dh * db) * n_attn
        add("attention", attn_f, attn_b, dp * h_par)
        add("o_proj", 2 * T * D * H * dh * n_attn,
            H * dh * D * db * n_attn * (microbatches if train else 1), dp * w_par)
        if cfg.is_moe:
            kt, E = cfg.experts_per_token, cfg.n_experts
            add("moe_ffn", 6 * T * kt * D * F * Lh,
                3 * E * D * F * db * Lh * (microbatches if train else 1),
                dp * w_par)
            add("router", 2 * T * D * E * Lh, T * E * 4 * Lh, dp)
        else:
            add("ffn", 6 * T * D * F * Lh,
                3 * D * F * db * Lh * (microbatches if train else 1)
                + 4 * T * F * db * Lh, dp * w_par)
        act_b = 8 * T * D * db * n_attn
        add("residual_norms", 0.0, act_b, dp)

    if cfg.family in ("ssm", "hybrid"):
        Din, N = cfg.d_inner, cfg.ssm_state
        Hs, P = cfg.ssm_heads, cfg.ssm_head_dim
        Q = cfg.ssm_chunk
        G = cfg.ssm_groups
        Lm = Lh
        Tm = B * S if kind in ("train", "prefill") else B * 1
        proj_f = 2 * Tm * D * (2 * Din + 2 * G * N + Hs) * Lm
        ssd_tok = 2 * Q * N + 2 * Q * Hs * P + 4 * N * Hs * P
        ssd_f = (Tm * ssd_tok if kind in ("train", "prefill")
                 else Tm * 4 * N * Hs * P)          # decode: recurrent update
        out_f = 2 * Tm * Din * D * Lm
        ssm_par = tp if Hs % tp == 0 else 1
        add("ssm_proj", proj_f,
            D * (2 * Din + 2 * G * N + Hs) * db * Lm
            * (microbatches if train else 1) + 6 * Tm * Din * db * Lm, dp)
        add("ssd_scan", ssd_f * Lm, 6 * Tm * (Hs * P + N) * 4 * Lm,
            dp * ssm_par)
        add("ssm_out", out_f, Din * D * db * Lm
            * (microbatches if train else 1), dp * ssm_par)

    # logits / loss (C1 stage)
    if train:
        # chunked CE is remat'd: fwd + recompute + dL/dh + dL/dW = 4 × 2TDV
        comps.append(Component(
            "loss_logits", 8.0 * T * D * V,
            3 * (V * D * db * microbatches + T * D * db + T * V * 4),
            dp * w_par))
        # optimizer: read P,m,v + write (f32 moments)
        n = cfg.n_params()
        comps.append(Component("adamw", 10.0 * n, n * (db + 16.0), dp * tp))
    else:
        t_logit = B * (32 if kind == "prefill" else 1)
        comps.append(Component(
            "decode_logits", 2.0 * t_logit * D * V,
            V * D * db + t_logit * V * 4, w_par))
    if kind == "prefill" and cfg.has_attention:
        # C3 selection: scoring + pack gather
        n_attn = Lh if cfg.family != "hybrid" else Lh // cfg.shared_attn_interval
        comps.append(Component(
            "select_pack", 2.0 * B * 32 * H * dh * S * n_attn,
            2.0 * B * S * K * dh * db * n_attn, dp))

    return comps


def analytic_cost(cfg: ModelConfig, shape: ShapeConfig, *, dp: int, tp: int,
                  **kw) -> Dict[str, float]:
    comps = components(cfg, shape, dp=dp, tp=tp, **kw)
    fl = sum(c.per_device()[0] for c in comps)
    by = sum(c.per_device()[1] for c in comps)
    top = sorted(comps, key=lambda c: -c.per_device()[0])[:3]
    return {
        "flops_per_device": fl,
        "bytes_per_device": by,
        "flops_global": sum(c.flops for c in comps),
        "top_components": [
            dict(name=c.name, flops_dev=c.per_device()[0],
                 bytes_dev=c.per_device()[1]) for c in top],
    }
