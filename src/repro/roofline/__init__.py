from repro.roofline.analysis import Roofline, analyze_compiled  # noqa: F401
