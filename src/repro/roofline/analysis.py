"""Roofline analysis from compiled XLA artifacts (no hardware required).

Terms (per device; the post-SPMD HLO module is already per-device):

  compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes_accessed / HBM_bw       (819 GB/s)
  collective = Σ collective output bytes / ICI   (~50 GB/s/link)

``HLO_FLOPs``/``bytes accessed`` come from ``compiled.cost_analysis()``
(verified per-device: a 512-way sharded einsum reports 1/512 of global
FLOPs). Collective bytes are parsed from the optimized HLO text: the result
shapes of every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute. For ring all-reduce the wire traffic is 2(n−1)/n × bytes
and for all-gather (n−1)/n — we report raw result bytes (uniform,
conservative) and note the convention in EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Dict

# v5e hardware constants (per chip) — given in the assignment.
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^ ]*))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# computation header: "%name (args...) -> result {" or "ENTRY %name ...".
# args may contain nested tuple parens -> match the whole line greedily.
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$", re.M)
_WHILE_RE = re.compile(
    r"while\([^)]*\)[^\n]*?condition=%?([\w.\-]+)[^\n]*?body=%?([\w.\-]+)"
    r"|while\([^)]*\)[^\n]*?body=%?([\w.\-]+)[^\n]*?condition=%?([\w.\-]+)")
_TRIP_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """Map computation name -> its body text."""
    comps: Dict[str, str] = {}
    matches = list(_COMP_RE.finditer(hlo_text))
    for i, m in enumerate(matches):
        end = matches[i + 1].start() if i + 1 < len(matches) else len(hlo_text)
        comps[m.group(1)] = hlo_text[m.start():end]
    return comps


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Loop-corrected collective result bytes per kind.

    XLA HLO text lists each while-loop body ONCE; a collective inside a
    layer scan must be multiplied by the trip count (and nested loops
    compound). We walk computations from the entry, multiplying by each
    while's trip count (largest integer constant in its condition — the
    standard counted-loop pattern jax scans lower to).
    """
    comps = _split_computations(hlo_text)
    entry = None
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        # fallback: flat sum
        out: Dict[str, int] = {}
        for shape_str, kind in _COLL_RE.findall(hlo_text):
            out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
        return out

    def trip_count(cond_name: str) -> int:
        body = comps.get(cond_name, "")
        consts = [int(c) for c in _TRIP_RE.findall(body)]
        return max(consts) if consts else 1

    out: Dict[str, int] = {}
    seen_stack = []

    def walk(name: str, mult: int):
        if name not in comps or name in seen_stack:
            return
        seen_stack.append(name)
        text = comps[name]
        for shape_str, kind in _COLL_RE.findall(text):
            out[kind] = out.get(kind, 0) + _shape_bytes(shape_str) * mult
        for wm in _WHILE_RE.finditer(text):
            cond = wm.group(1) or wm.group(4)
            body = wm.group(2) or wm.group(3)
            if body:
                walk(body, mult * trip_count(cond) if cond else mult)
        # non-while called computations (fusions/maps) execute once per call
        # site; their collectives (rare) are attributed at mult.
        seen_stack.pop()

    walk(entry, 1)
    return out


_UPCAST_RE = re.compile(
    r"=\s*f32\[([0-9,]+)\][^\n]*?(?:convert|wrapped_convert[^\n(]*fusion)\(")


def f32_upcast_bytes(hlo_text: str, min_rank: int = 3) -> int:
    """Bytes of wholesale bf16→f32 parameter/cache copies XLA:CPU inserts
    (CPU has no native bf16 matmul). On TPU these conversions don't exist;
    subtracting them gives the TPU-side temp estimate. Only rank≥3 tensors
    are counted (weight stacks / KV caches), not small activation upcasts.
    """
    total = 0
    for m in _UPCAST_RE.finditer(hlo_text):
        dims = [int(x) for x in m.group(1).split(",") if x]
        if len(dims) < min_rank:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * 4
    return total


@dataclass
class Roofline:
    name: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    temp_bytes_per_device: int = 0
    arg_bytes_per_device: int = 0
    model_flops: float = 0.0         # 6ND (train) / 2ND (serve), global
    hlo_flops_raw: float = 0.0       # cost_analysis (loop-undercounted)
    hlo_bytes_raw: float = 0.0
    top_components: list = field(default_factory=list)
    f32_upcast_bytes: int = 0        # CPU-backend bf16->f32 copy artifact

    @property
    def temp_bytes_tpu_estimate(self) -> int:
        """Per-device temp with the CPU-only f32 weight copies removed."""
        return max(0, self.temp_bytes_per_device - self.f32_upcast_bytes)

    # -- derived terms (seconds) ------------------------------------------
    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step time: overlapped model = max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips): remat/dispatch waste detector."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline step time."""
        denom = self.step_time * PEAK_FLOPS * self.chips
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 step_time=self.step_time, mfu=self.mfu,
                 useful_flops_ratio=self.useful_flops_ratio,
                 temp_bytes_tpu_estimate=self.temp_bytes_tpu_estimate)
        return d

    def row(self) -> str:
        return (f"{self.name:42s} comp={self.t_compute*1e3:9.3f}ms "
                f"mem={self.t_memory*1e3:9.3f}ms coll={self.t_collective*1e3:9.3f}ms "
                f"[{self.bottleneck:10s}] mfu={self.mfu*100:5.1f}% "
                f"useful={self.useful_flops_ratio*100:5.1f}%")


def analyze_compiled(name: str, compiled, chips: int, model_flops: float,
                     analytic: dict | None = None) -> Roofline:
    """Roofline record for one compiled cell.

    ``analytic``: output of ``flops.analytic_cost`` — used for the compute/
    memory terms because cost_analysis undercounts loop bodies (docstring
    above). HLO-reported numbers are preserved in ``hlo_*_raw`` for
    reference; collective bytes are loop-corrected from the HLO itself.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # jax 0.4.x: one dict per device kind
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    upcast = f32_upcast_bytes(hlo_text)
    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    r = Roofline(
        name=name,
        chips=chips,
        flops_per_device=(analytic["flops_per_device"] if analytic
                          else flops_dev),
        bytes_per_device=(analytic["bytes_per_device"] if analytic
                          else bytes_dev),
        coll_bytes_per_device=float(sum(coll.values())),
        coll_breakdown=coll,
        temp_bytes_per_device=int(ma.temp_size_in_bytes),
        arg_bytes_per_device=int(ma.argument_size_in_bytes),
        model_flops=model_flops,
    )
    r.hlo_flops_raw = flops_dev
    r.hlo_bytes_raw = bytes_dev
    r.f32_upcast_bytes = upcast
    if analytic:
        r.top_components = analytic.get("top_components", [])
    return r


def save_records(path: str, records: list) -> None:
    with open(path, "w") as f:
        json.dump([r if isinstance(r, dict) else r.to_dict() for r in records],
                  f, indent=1)
