"""Production mesh definitions (single-pod 16×16, multi-pod 2×16×16).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (required so smoke tests see 1 device while the dry-run sees
512 placeholder devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    import os
    override = os.environ.get("REPRO_MESH")  # e.g. "2,4" (CI-scale tests)
    if override:
        dims = tuple(int(x) for x in override.split(","))
        axes = (("pod", "data", "model") if len(dims) == 3
                else ("data", "model"))
        return jax.make_mesh(dims, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """The axes batches shard over (pods fold into data parallelism)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh, name) -> int:
    if isinstance(name, (tuple, list)):
        out = 1
        for n in name:
            out *= axis_size(mesh, n)
        return out
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def small_test_mesh(n_data: int = 2, n_model: int = 2):
    """Tiny mesh for CPU subprocess tests (requires host device override)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def _axes_for(ndim: int) -> tuple:
    axes = {2: ("data", "model"), 3: ("pod", "data", "model")}.get(ndim)
    if axes is None:
        raise ValueError(
            f"mesh shape needs 2 dims (data, model) or 3 (pod, data, "
            f"model), got {ndim} — e.g. --mesh 1,2 / REPRO_MESH=1,2")
    return axes


def parse_mesh_env(var: str = "REPRO_MESH"):
    """``ServeConfig.mesh_shape`` from the env (e.g. ``REPRO_MESH=1,2``).

    Returns None when unset/empty — the serving CLI and CI smoke use this so
    the same invocation runs unsharded by default and mesh-sharded under the
    2-host-device repro environment."""
    import os
    raw = os.environ.get(var, "").strip()
    if not raw:
        return None
    return tuple(int(x) for x in raw.split(","))


def make_serving_mesh(mesh_shape):
    """The engine's serving mesh: ``mesh_shape`` -> a real device mesh.

    None means "no mesh" (single-device engine, returns None). Anything else
    demands the devices exist: ``jax.make_mesh`` raises when the host exposes
    fewer devices than the shape needs, so a mis-set environment fails loudly
    instead of silently collapsing to one device."""
    if not mesh_shape:
        return None
    mesh_shape = tuple(int(d) for d in mesh_shape)
    return jax.make_mesh(mesh_shape, _axes_for(len(mesh_shape)))


class SimMesh:
    """Device-free stand-in for a mesh: only ``axis_names`` + device *shape*.

    ``Rules`` and :func:`axis_size` consult nothing else, so the offline
    memory profiler can bill per-device bytes for meshes far larger than the
    host (e.g. a simulated 2-GPU mesh inside a 1-CPU test process). Not
    usable for placement — ``Rules.named`` needs a real mesh."""

    class _Devices:
        def __init__(self, shape):
            self.shape = tuple(shape)
            self.size = 1
            for d in shape:
                self.size *= d

    def __init__(self, shape, axes=None):
        shape = tuple(int(d) for d in shape)
        self.axis_names = tuple(axes) if axes else _axes_for(len(shape))
        assert len(self.axis_names) == len(shape), (shape, self.axis_names)
        self.devices = SimMesh._Devices(shape)
        self.shape = dict(zip(self.axis_names, shape))
