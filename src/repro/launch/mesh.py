"""Production mesh definitions (single-pod 16×16, multi-pod 2×16×16).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (required so smoke tests see 1 device while the dry-run sees
512 placeholder devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    import os
    override = os.environ.get("REPRO_MESH")  # e.g. "2,4" (CI-scale tests)
    if override:
        dims = tuple(int(x) for x in override.split(","))
        axes = (("pod", "data", "model") if len(dims) == 3
                else ("data", "model"))
        return jax.make_mesh(dims, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """The axes batches shard over (pods fold into data parallelism)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh, name) -> int:
    if isinstance(name, (tuple, list)):
        out = 1
        for n in name:
            out *= axis_size(mesh, n)
        return out
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def small_test_mesh(n_data: int = 2, n_model: int = 2):
    """Tiny mesh for CPU subprocess tests (requires host device override)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
