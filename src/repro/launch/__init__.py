# Launch layer: production mesh, sharding rules, multi-pod dry-run,
# train/serve drivers.
