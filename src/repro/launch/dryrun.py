import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "REPRO_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# --- multi-pod dry-run: lower + compile every (arch × shape × mesh) cell ---
#
# The two lines above run before ANY other import (jax locks the device count
# on first init). 512 placeholder host devices back the production meshes:
# single-pod (16,16)=(data,model) and multi-pod (2,16,16)=(pod,data,model).
#
# For each cell this driver:
#   1. builds the arch's step function for the shape kind
#      (train_4k -> train_step; prefill_32k -> serve_refresh + C1 decode;
#       decode_32k / long_500k -> serve_reuse + C1 decode),
#   2. builds ShapeDtypeStruct inputs with production shardings (no
#      allocation),
#   3. .lower().compile()s under the mesh — success proves the distribution
#      config is coherent,
#   4. records memory_analysis / cost_analysis / HLO collective bytes into a
#      JSON roofline record (EXPERIMENTS.md §Dry-run and §Roofline read it).
#
# Usage:
#   python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
#   python -m repro.launch.dryrun --all --multipod --out results/dryrun.json

import argparse
import dataclasses
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro import jax_compat as JC
from repro.configs import ASSIGNED, SHAPES_BY_NAME, get_config
from repro.configs.base import ModelConfig, ServeConfig, ShapeConfig, TrainConfig
from repro.launch.mesh import axis_size, data_axes, make_production_mesh
from repro.launch.sharding import Rules
from repro.models import backbone as BB
from repro.models import lm_head as LM
from repro.models import transformer as T
from repro.models.sparse_select import PackedKV
from repro.roofline.analysis import analyze_compiled

BLOCK = 32                 # dLLM active block (paper Table 3)
RETENTION = 0.5            # paper default r
MAX_NUM_LOGITS = 2048      # paper Table 3
Q_CHUNK = 1024             # refresh attention query tile


def sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=jax.sharding.NamedSharding(mesh, spec))


def param_structs(cfg: ModelConfig, mesh, rules: Rules):
    shapes = jax.eval_shape(partial(BB.init_params, cfg),
                            jax.random.PRNGKey(0))
    specs = rules.params(shapes)
    return jax.tree.map(
        lambda l, s: sds(l.shape, l.dtype, mesh, s), shapes, specs), shapes


def serve_ctx(cfg: ModelConfig, shape: ShapeConfig, *, block: int,
              retention: float, selection: str) -> T.ServeContext:
    retain = max(block, int(shape.seq_len * retention))
    # keep SSD chunking + retained length block-aligned
    retain = -(-retain // block) * block
    # prefill at 32k: a [B, H, q_chunk, S] f32 score tile must stay ≲2 GiB
    # per device -> shrink the query tile for long refreshes
    qc = Q_CHUNK if shape.seq_len <= 8192 else 256
    return T.ServeContext(block_size=block, retain=retain, kernel_size=3,
                          selection=selection, q_chunk=qc)


def text_len(cfg: ModelConfig, S: int) -> int:
    return S - (cfg.frontend_len if cfg.frontend_dim else 0)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N_active·D for training; 2·N_active·D for forward-only serving."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    # decode: one active block of 1 token per sequence
    return 2.0 * n * shape.global_batch


# ---------------------------------------------------------------------------
# cell builders: (fn, example_args) per shape kind
# ---------------------------------------------------------------------------

def build_train(cfg: ModelConfig, shape: ShapeConfig, mesh,
                tc: TrainConfig):
    from repro.train.optimizer import init_opt_state
    from repro.train.train_loop import make_train_step

    rules = Rules(cfg, mesh, train=True)
    params, pshapes = param_structs(cfg, mesh, rules)
    oshape = jax.eval_shape(init_opt_state, pshapes)
    ospecs = rules.opt_state(pshapes)
    opt = jax.tree.map(lambda l, s: sds(l.shape, l.dtype, mesh, s),
                       oshape, ospecs)
    G, S = shape.global_batch, text_len(cfg, shape.seq_len)
    tokens = sds((G, S), jnp.int32, mesh, rules.tokens(G))
    rng = sds((), jnp.uint32, mesh, jax.sharding.PartitionSpec())
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
    step = make_train_step(cfg, tc)
    args = (params, opt, tokens, rng)
    if cfg.frontend_dim:
        fe = sds((G, cfg.frontend_len, cfg.frontend_dim), jnp.bfloat16, mesh,
                 rules.frontend())
        args = args + (fe,)
    return step, args


def build_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh,
                  selection: str, retention: float, logit_mode: str,
                  flash_refresh: bool = False):
    rules = Rules(cfg, mesh, train=False)
    params, _ = param_structs(cfg, mesh, rules)
    B, S = shape.global_batch, shape.seq_len
    St = text_len(cfg, S)
    ctx = serve_ctx(cfg, shape, block=BLOCK, retention=retention,
                    selection=selection)
    if flash_refresh:
        ctx = dataclasses.replace(ctx, use_flash_refresh=True)

    def step(params, tokens, block_start, token_valid, frontend=None):
        out = BB.serve_refresh(params, cfg, tokens, block_start, ctx,
                               frontend=frontend, token_valid=token_valid)
        h = out.block_hidden.reshape(-1, cfg.d_model)
        ids, conf = LM.decode_tokens(params["embed"], cfg, h,
                                     max_num_logits=MAX_NUM_LOGITS,
                                     mode=logit_mode)
        return ids, conf, out.cache

    dp = rules.tokens(B)
    args = (params,
            sds((B, St), jnp.int32, mesh, dp),
            sds((B,), jnp.int32, mesh,
                jax.sharding.PartitionSpec(dp[0] if B % axis_size(mesh, rules.dp) == 0 else None)),
            sds((B, S), jnp.bool_, mesh, dp))
    if cfg.frontend_dim:
        args = args + (sds((B, cfg.frontend_len, cfg.frontend_dim),
                           jnp.bfloat16, mesh, rules.frontend()),)
    return step, args


def cache_structs(cfg: ModelConfig, mesh, rules: Rules, batch: int,
                  retain: int):
    """ShapeDtypeStructs for the serving cache of each family."""
    dt = jnp.dtype(cfg.dtype)
    spec = rules.cache(batch, retain)
    dh = cfg.resolved_head_dim if cfg.n_heads else 0
    K = cfg.n_kv_heads

    def kv_struct(n_layers, sp: PackedKV):
        kshape = (n_layers, batch, K, retain, dh)
        mshape = (n_layers, batch, K, retain)
        return PackedKV(
            k=sds(kshape, dt, mesh, sp.k), v=sds(kshape, dt, mesh, sp.v),
            pos=sds(mshape, jnp.int32, mesh, sp.pos),
            valid=sds(mshape, jnp.bool_, mesh, sp.valid))

    if cfg.family == "ssm":
        from repro.models.ssm import SSMCache, conv_channels
        st = (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim,
              cfg.ssm_state)
        cv = (cfg.n_layers, batch, cfg.ssm_conv_kernel - 1,
              conv_channels(cfg))
        return SSMCache(state=sds(st, jnp.float32, mesh, spec.state),
                        conv=sds(cv, dt, mesh, spec.conv))
    if cfg.family == "hybrid":
        from repro.models.hybrid import HybridCache, group_shape
        from repro.models.ssm import conv_channels
        n_groups, _, _ = group_shape(cfg)
        st = (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim,
              cfg.ssm_state)
        cv = (cfg.n_layers, batch, cfg.ssm_conv_kernel - 1,
              conv_channels(cfg))
        return HybridCache(
            ssm_state=sds(st, jnp.float32, mesh, spec.ssm_state),
            conv=sds(cv, dt, mesh, spec.conv),
            kv=kv_struct(n_groups, spec.kv))
    return kv_struct(cfg.n_layers, spec)


def build_decode(cfg: ModelConfig, shape: ShapeConfig, mesh,
                 selection: str, retention: float, logit_mode: str):
    rules = Rules(cfg, mesh, train=False)
    params, _ = param_structs(cfg, mesh, rules)
    B, S = shape.global_batch, shape.seq_len
    Sb = 1   # decode shapes: one new token over a seq_len KV cache
    ctx = dataclasses.replace(
        serve_ctx(cfg, shape, block=BLOCK, retention=retention,
                  selection=selection), block_size=Sb)
    retain = max(BLOCK, int(S * retention))
    retain = -(-retain // BLOCK) * BLOCK
    cache = cache_structs(cfg, mesh, rules, B, retain)

    def step(params, btok, bpos, cache):
        h = BB.serve_reuse(params, cfg, btok, bpos, cache, ctx)
        ids, conf = LM.decode_tokens(params["embed"], cfg,
                                     h.reshape(-1, cfg.d_model),
                                     max_num_logits=MAX_NUM_LOGITS,
                                     mode=logit_mode)
        return ids, conf

    dpn = axis_size(mesh, rules.dp)
    bspec = rules.dp if B % dpn == 0 and B >= dpn else None
    args = (params,
            sds((B, Sb), jnp.int32, mesh, jax.sharding.PartitionSpec(bspec, None)),
            sds((B, Sb), jnp.int32, mesh, jax.sharding.PartitionSpec(bspec, None)),
            cache)
    return step, args


# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             selection: str = "head", retention: float = RETENTION,
             logit_mode: str = "chunked", moe_impl: str = "gather",
             microbatches: int = 8, grad_compression: str = "none",
             opt_loss: bool = False, flash_refresh: bool = False,
             pad_vocab: bool = False, loss_chunk: int = MAX_NUM_LOGITS,
             verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    from jax.sharding import PartitionSpec as P
    from repro.models import layers as Lmod
    dp = data_axes(mesh)
    policy = {"act3d": P(dp, None, None)}
    if opt_loss:
        # §Perf "CE reshard": vocab-parallel head weight at the point of use
        # + chunk tokens spread over data (one hoisted weight all-gather
        # instead of per-chunk [chunk, V] partial-product all-reduces)
        policy.update({
            "logit_w": P(None, "model"),
            "logit_w_tied": P("model", None),
            "loss_h3": P(None, dp, None),
        })
    Lmod.set_sharding_policy(policy)
    cfg = get_config(arch)
    if cfg.is_moe and moe_impl != cfg.moe_impl:
        cfg = dataclasses.replace(cfg, moe_impl=moe_impl)
    if pad_vocab and cfg.vocab_size % 128:
        # Megatron-style vocab padding: shardability for CE/logits
        v = -(-cfg.vocab_size // 128) * 128
        cfg = dataclasses.replace(cfg, vocab_size=v)
    shape = SHAPES_BY_NAME[shape_name]
    name = f"{arch}×{shape_name}×{'2x16x16' if multi_pod else '16x16'}"
    t0 = time.time()
    if shape.kind == "train":
        tc = TrainConfig(microbatches=microbatches, remat=True,
                         loss_chunk=loss_chunk,
                         grad_compression=grad_compression)
        fn, args = build_train(cfg, shape, mesh, tc)
    elif shape.kind == "prefill":
        fn, args = build_prefill(cfg, shape, mesh, selection, retention,
                                 logit_mode, flash_refresh=flash_refresh)
    else:
        fn, args = build_decode(cfg, shape, mesh, selection, retention,
                                logit_mode)

    from repro.jax_compat import use_mesh
    with use_mesh(mesh):
        lowered = JC.jit(fn).lower(*args)
        compiled = lowered.compile()
    # per-device bf16 argument bytes: XLA:CPU upcasts every bf16 weight/cache
    # operand to f32 (2x its size) — a backend artifact absent on TPU. Used
    # to bound the TPU-side temp estimate.
    import numpy as _np
    bf16_args = 0
    for leaf in jax.tree.leaves(args):
        if getattr(leaf, "dtype", None) == jnp.bfloat16 and leaf.sharding:
            sh = leaf.sharding.shard_shape(leaf.shape)
            bf16_args += int(_np.prod(sh)) * 2
    from repro.roofline.flops import analytic_cost
    dp_n = axis_size(mesh, data_axes(mesh))
    tp_n = axis_size(mesh, "model")
    analytic = analytic_cost(cfg, shape, dp=dp_n, tp=tp_n,
                             retention=retention, microbatches=microbatches,
                             remat=True, q_chunk=Q_CHUNK,
                             flash_refresh=flash_refresh)
    roof = analyze_compiled(name, compiled, chips, model_flops(cfg, shape),
                            analytic=analytic)
    roof.f32_upcast_bytes = 2 * bf16_args
    rec = roof.to_dict()
    rec.update(arch=arch, shape=shape_name,
               mesh="2x16x16" if multi_pod else "16x16",
               selection=selection, retention=retention,
               logit_mode=logit_mode, moe_impl=moe_impl,
               opt_loss=opt_loss, flash_refresh=flash_refresh,
               pad_vocab=pad_vocab,
               compile_s=round(time.time() - t0, 1), ok=True)
    if verbose:
        ma = compiled.memory_analysis()
        print(f"[ok] {name}  compile={rec['compile_s']}s")
        print(f"     mem/device: args={ma.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
              f"(tpu-est {roof.temp_bytes_tpu_estimate/2**30:.2f}GiB) "
              f"out={ma.output_size_in_bytes/2**30:.2f}GiB")
        print("     " + roof.row())
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--selection", default="head")
    ap.add_argument("--retention", type=float, default=RETENTION)
    ap.add_argument("--logit-mode", default="chunked")
    ap.add_argument("--moe-impl", default="gather")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--grad-compression", default="none")
    ap.add_argument("--opt-loss", action="store_true",
                    help="CE reshard optimization (hillclimb)")
    ap.add_argument("--flash-refresh", action="store_true",
                    help="Pallas flash kernel for Refresh attention")
    ap.add_argument("--pad-vocab", action="store_true",
                    help="pad vocab to a 128 multiple for shardability")
    ap.add_argument("--loss-chunk", type=int, default=MAX_NUM_LOGITS)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    archs = list(ASSIGNED) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES_BY_NAME) if args.all or not args.shape \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multipod]

    # activation budget: the two 80-layer dense archs need deeper grad
    # accumulation to keep per-layer remat residuals under 16 GiB/chip
    DEEP_ACCUM = {"qwen2-72b", "internvl2-76b"}

    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mb = 16 if arch in DEEP_ACCUM and shape == "train_4k" \
                    else args.microbatches
                try:
                    rec = run_cell(arch, shape, multi_pod=mp,
                                   selection=args.selection,
                                   retention=args.retention,
                                   logit_mode=args.logit_mode,
                                   moe_impl=args.moe_impl,
                                   microbatches=mb,
                                   grad_compression=args.grad_compression,
                                   opt_loss=args.opt_loss,
                                   flash_refresh=args.flash_refresh,
                                   pad_vocab=args.pad_vocab,
                                   loss_chunk=args.loss_chunk)
                except Exception as e:
                    traceback.print_exc()
                    rec = dict(arch=arch, shape=shape,
                               mesh="2x16x16" if mp else "16x16",
                               ok=False, error=f"{type(e).__name__}: {e}")
                    print(f"[FAIL] {arch}×{shape}: {e}")
                records.append(rec)
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(records, f, indent=1)
    n_ok = sum(r["ok"] for r in records)
    print(f"\n{n_ok}/{len(records)} cells compiled successfully")
    return 0 if n_ok == len(records) else 1


if __name__ == "__main__":
    raise SystemExit(main())
