"""Serving launcher: run the dLLM-Serve engine over a synthetic workload.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch llada-8b --reduced \
      --system dllm-serve --workload burst --rps 2.0 --n 12

Mesh serving: ``--mesh 1,2`` (or ``REPRO_MESH=1,2`` in the environment) runs
the whole packed pipeline tensor-parallel on a (data, model) device mesh —
the host must expose the devices (CPU repro:
``XLA_FLAGS=--xla_force_host_platform_device_count=2``); a mesh that cannot
be built fails loudly instead of collapsing to one device, and the result
JSON records ``mesh_devices`` so harnesses can assert it. ``--mesh none``
forces the single-device engine even when ``REPRO_MESH`` is set.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Optional, Tuple

import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ServeConfig
from repro.core.baselines import size_slots, system_profiles
from repro.core.budgeting import plan_memory
from repro.core.engine import Engine
from repro.core.faults import FaultPlan
from repro.core.request import State
from repro.data.workloads import make_trace, prefix_share_factor, \
    trace_prompts
from repro.launch.mesh import parse_mesh_env


def run_serve(arch: str, system: str, workload: str, rps: float, n: int,
              use_reduced: bool = True, seed: int = 0,
              max_seq_len: int = 256, block_size: int = 8,
              steps_per_block: int = 8, max_slots: int = 12,
              max_num_batched_tokens: int = 1024, max_num_logits: int = 128,
              time_scale: float = 1.0, length_scale: float = 0.15,
              size_by_profiler: bool = True, hbm_gb: int = 24,
              clock: str = "modeled", quiet: bool = True,
              mesh_shape: Optional[Tuple[int, ...]] = None,
              queue_cap: int = 0, queue_policy: str = "reject",
              deadline_slack: float = float("inf"),
              preempt_starvation_s: float = 0.0,
              fault_seed: Optional[int] = None,
              kernels: Optional[bool] = None,
              prefix_sharing: bool = False,
              kv_quant: str = "none",
              pipeline: bool = True,
              stream: bool = False) -> dict:
    import dataclasses
    cfg = get_config(arch)
    full_cfg = cfg
    if use_reduced:
        cfg = reduced(cfg)
    base = ServeConfig(
        max_num_batched_tokens=max_num_batched_tokens,
        max_num_logits=max_num_logits, block_size=block_size,
        steps_per_block=steps_per_block, max_seq_len=max_seq_len,
        max_slots=max_slots, max_refresh_per_iter=4,
        mesh_shape=tuple(mesh_shape) if mesh_shape else None,
        queue_cap=queue_cap, queue_policy=queue_policy,
        preempt_starvation_s=preempt_starvation_s,
        prefix_sharing=prefix_sharing, kv_quant=kv_quant,
        pipeline=pipeline)
    serve = system_profiles(base)[system]
    if kernels:
        # Pallas hot paths on top of the system profile (shard_mapped per
        # model shard under a mesh — validated at engine construction, no
        # silent fallback); kernels=False pins the jnp fallback paths
        serve = dataclasses.replace(serve, use_flash_kernel=True,
                                    logit_mode="fused")
    elif kernels is not None:
        serve = dataclasses.replace(serve, use_flash_kernel=False,
                                    logit_mode="chunked")
    # trace first: the profiler's sharing-aware sizing reads the trace's
    # measured share factor (a pure function of the trace, drawn before any
    # engine state exists — sizing cannot perturb the workload stream)
    trace = make_trace(workload, n, rps, seed=seed, scale=length_scale,
                       deadline_slack=deadline_slack)
    share = prefix_share_factor(trace) if serve.prefix_sharing else 1.0
    plan = None
    if size_by_profiler:
        # Offline profiler (§4.2) at FULL-model geometry and paper Table 3
        # settings decides each system's concurrency: monolithic logit
        # reservations and dense caches buy fewer KV slots — the paper's
        # capacity coupling, carried into the (scaled) serving run. The
        # mesh_shape rides along, so an N-device mesh is sized by its
        # per-device arithmetic (hbm_gb = one device's HBM). Sharing and
        # int8 KV lift the plan's capacity (docs/memory.md); the engine's
        # allocation clamps to PHYSICAL capacity (size_slots).
        plan_serve = dataclasses.replace(
            serve, max_seq_len=2048, max_num_batched_tokens=4000,
            max_num_logits=2048, max_slots=max_slots)
        plan = plan_memory(full_cfg, plan_serve, hbm_gb << 30,
                           share_factor=share)
        sized = size_slots(full_cfg, plan_serve, hbm_gb << 30,
                           share_factor=share)
        serve = dataclasses.replace(serve,
                                    max_slots=max(1, sized.max_slots))
    faults = FaultPlan.seeded(fault_seed) if fault_seed is not None else None
    stream_cb = None
    if stream:
        # per-commit streaming: one event per request per iteration, fired
        # at the deferred sync — the first host-side moment the token
        # values exist. The launcher prints a compact line per event (the
        # JSON still carries the aggregate streamed_events count).
        def stream_cb(ev):
            if not quiet:
                tok = ev["tokens"][:4]
                print(f"  stream rid={ev['rid']} block={ev['block_idx']} "
                      f"+{ev['n_committed']} tok "
                      f"{'FIN ' if ev['finished'] else ''}{tok}...")
    eng = Engine(cfg, serve, seed=seed, clock=clock, faults=faults,
                 stream_cb=stream_cb)
    if mesh_shape and not quiet:
        print(f"mesh: {eng.mesh_devices} devices "
              f"({'x'.join(map(str, serve.mesh_shape))})")
    warmup_s = eng.warmup()      # AOT compile outside the measured window
    prompts = trace_prompts(trace, cfg.vocab_size, seed=seed)
    reqs = []
    for i, (t, p) in enumerate(zip(trace, prompts)):
        gl = min(t.gen_len, max_seq_len - len(p) - block_size)
        gl = max(block_size, gl)
        pl = min(len(p), max_seq_len - gl - block_size)
        reqs.append(eng.submit(p[:pl], gen_len=gl, arrival=t.arrival, rid=i,
                               deadline=t.deadline))
    t_run0 = time.perf_counter()
    stats = eng.run(time_scale=time_scale, quiet=quiet)
    host_elapsed_s = time.perf_counter() - t_run0
    # latency percentiles over FINISHED requests only — shed/rejected
    # requests have no completion time and must not skew (or zero) the tail
    fin = [r for r in reqs if r.state == State.FINISHED]
    lats = np.array([r.latency for r in fin]) if fin else np.zeros(1)
    # goodput: tokens of requests that finished BEFORE their deadline —
    # shedding (or blowing deadlines) can't masquerade as throughput
    good_tokens = sum(r.gen_len for r in fin if r.met_deadline)
    out = dict(
        system=system, workload=workload, rps=rps, n=n,
        throughput_tok_s=stats.throughput,
        goodput_tok_s=good_tokens / max(stats.wall_time, 1e-9),
        committed_tokens=stats.committed_tokens,
        wall_time=stats.wall_time,
        n_submitted=stats.submitted,
        n_finished=stats.finished,
        n_shed=stats.shed,
        n_rejected=stats.rejected,
        shed_deadline=stats.shed_deadline,
        shed_queue=stats.shed_queue,
        rejected_oversized=stats.rejected_oversized,
        rejected_queue_full=stats.rejected_queue_full,
        n_preemptions=stats.preemptions,
        recomputed_tokens=stats.recomputed_tokens,
        dispatch_retries=stats.dispatch_retries,
        alloc_fault_iters=stats.alloc_fault_iters,
        avg_latency=float(lats.mean()),
        p50_latency=float(np.percentile(lats, 50)),
        p99_latency=float(np.percentile(lats, 99)),
        latency_std=float(lats.std()),
        tail_span=float(lats.max() - lats.min()),
        refresh_steps=stats.refresh_steps,
        reuse_steps=stats.reuse_steps,
        deferred=stats.deferred_steps,
        peak_query_tokens=stats.peak_query_tokens,
        refresh_tokens_real=stats.refresh_tokens_real,
        refresh_tokens_exec=stats.refresh_tokens_exec,
        refresh_waste=stats.refresh_waste,
        reuse_tokens_real=stats.reuse_tokens_real,
        reuse_tokens_exec=stats.reuse_tokens_exec,
        reuse_waste=stats.reuse_waste,
        logit_tokens_real=stats.logit_tokens_real,
        logit_tokens_exec=stats.logit_tokens_exec,
        logit_waste=stats.logit_waste,
        packed_refresh_calls=stats.packed_refresh_calls,
        padded_refresh_calls=stats.padded_refresh_calls,
        packed_reuse_calls=stats.packed_reuse_calls,
        padded_reuse_calls=stats.padded_reuse_calls,
        warmup_s=warmup_s,
        # retrace sentinel (docs/analysis.md): per-entry compile counts and
        # the post-warmup budget — 0 on the padded path, lazily-compiled
        # sub-buckets only on the packed path
        compile_counts=dict(stats.compile_counts),
        compiles_warmup=stats.compiles_warmup,
        compiles_post_warmup=stats.compiles_post_warmup,
        # pipelined-loop accounting (docs/engine.md): the modeled clock
        # prices device work (throughput_tok_s above); these price the HOST
        # side — per-stage gaps and how much of them the dispatch-ahead
        # loop hid. wall_clock_s is true host elapsed around Engine.run, so
        # wall_tok_s is the end-to-end rate this process actually achieved.
        clock=clock,
        pipeline=serve.pipeline,
        iterations=stats.iterations,
        wall_clock_s=host_elapsed_s,
        wall_tok_s=stats.committed_tokens / max(host_elapsed_s, 1e-9),
        host_plan_s=stats.host_plan_s,
        host_fill_s=stats.host_fill_s,
        sync_wait_s=stats.sync_wait_s,
        overlapped_host_s=stats.overlapped_host_s,
        overlap_frac=stats.overlap_frac,
        dispatched_ahead=stats.dispatched_ahead,
        streamed_events=stats.streamed_events,
        host_profile=int(os.environ.get("REPRO_HOST_PROFILE", "0") or "0"),
        max_slots=serve.max_slots,
        # memory-footprint multipliers (docs/memory.md): what ran, what the
        # ledger measured, and what the profiler planned from the trace
        prefix_sharing=serve.prefix_sharing,
        kv_quant=serve.kv_quant,
        share_factor=share,
        shared_hits=stats.shared_hits,
        shared_cow_promotes=stats.shared_cow_promotes,
        phys_slots_peak=stats.phys_slots_peak,
        plan_slots_logical=plan.max_slots if plan else None,
        plan_slots_phys=plan.phys_slots if plan else None,
        plan_slot_bytes=plan.slot_bytes if plan else None,
        mesh_shape=list(serve.mesh_shape) if serve.mesh_shape else None,
        mesh_devices=eng.mesh_devices,
        # True when the Pallas hot paths served this run (under a mesh they
        # dispatched per-shard — the engine validates at construction and
        # never silently falls back to the jnp paths)
        kernels_active=eng.kernels_active,
        # per-device executed tokens under the engine's ACTUAL work split:
        # the sharded TP fraction (1.0 when no dim divides — an indivisible
        # mesh must not deflate this metric) × the data-axis replica streams
        refresh_tokens_exec_per_device=stats.refresh_tokens_exec
        / eng.work_split,
        reuse_tokens_exec_per_device=stats.reuse_tokens_exec
        / eng.work_split,
        logit_tokens_exec_per_device=stats.logit_tokens_exec
        / eng.work_split,
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llada-8b")
    ap.add_argument("--system", default="dllm-serve",
                    choices=["dllm-serve", "sparse-dllm", "fast-dllm",
                             "dllm-cache"])
    ap.add_argument("--workload", default="livebench")
    ap.add_argument("--rps", type=float, default=1.0)
    ap.add_argument("--n", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="use the full config (CPU-hostile; default reduced)")
    ap.add_argument("--mesh", default="env",
                    help="serving mesh: 'd,m' shape, 'none', or 'env' "
                         "(default: honor REPRO_MESH)")
    ap.add_argument("--queue-cap", type=int, default=0,
                    help="bounded waiting queue (0 = unbounded)")
    ap.add_argument("--queue-policy", default="reject",
                    choices=["reject", "evict"],
                    help="full-queue backpressure: reject new vs evict oldest")
    ap.add_argument("--deadline", type=float, default=float("inf"),
                    help="per-request deadline slack in trace seconds "
                         "(inf = none); expired waiters are shed")
    ap.add_argument("--preempt-starvation", type=float, default=0.0,
                    help="starvation threshold (s) that triggers "
                         "preempt-and-requeue (0 = disabled)")
    ap.add_argument("--faults", type=int, default=None, metavar="SEED",
                    help="run under a seeded FaultPlan (chaos mode)")
    ap.add_argument("--kernels", action="store_true",
                    help="force the Pallas hot paths (use_flash_kernel + "
                         "logit_mode=fused) on top of the system profile; "
                         "shard_mapped per model shard under a mesh")
    ap.add_argument("--sharing", action="store_true",
                    help="content-addressed prefix sharing in the KV pool "
                         "(COW on divergence; token output bit-identical "
                         "to sharing off — docs/memory.md)")
    ap.add_argument("--kv-quant", default="none", choices=["none", "int8"],
                    help="KV slot storage dtype (int8: per-slot abs-max "
                         "scales, dequantized at the Reuse KV load)")
    ap.add_argument("--clock", default="modeled",
                    choices=["modeled", "wall"],
                    help="iteration clock: 'modeled' prices device work on "
                         "the paper's cost model (deterministic, the "
                         "default); 'wall' timestamps with the host clock "
                         "so throughput reflects this machine")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="run the synchronous oracle loop (sync every "
                         "iteration) instead of the dispatch-ahead "
                         "pipelined loop; token output is bit-identical")
    ap.add_argument("--stream", action="store_true",
                    help="print a per-request commit event at each "
                         "iteration's deferred sync (first host-side "
                         "sight of the token values)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.mesh == "env":
        mesh_shape = parse_mesh_env()
    elif args.mesh in ("none", ""):
        mesh_shape = None
    else:
        mesh_shape = tuple(int(x) for x in args.mesh.split(","))
    res = run_serve(args.arch, args.system, args.workload, args.rps, args.n,
                    use_reduced=not args.full, seed=args.seed, quiet=False,
                    mesh_shape=mesh_shape, queue_cap=args.queue_cap,
                    queue_policy=args.queue_policy,
                    deadline_slack=args.deadline,
                    preempt_starvation_s=args.preempt_starvation,
                    fault_seed=args.faults,
                    kernels=True if args.kernels else None,
                    prefix_sharing=args.sharing, kv_quant=args.kv_quant,
                    clock=args.clock, pipeline=not args.no_pipeline,
                    stream=args.stream)
    print(json.dumps(res, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=2)


if __name__ == "__main__":
    main()
