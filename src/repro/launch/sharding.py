"""Sharding rules: param/optimizer/cache/batch PartitionSpecs for any arch.

Strategy (DESIGN.md §4):
  * **TP** over the ``model`` axis: attention heads, FFN hidden, MoE experts,
    vocab (vocab-parallel embedding + LM head).
  * **FSDP** over the ``data`` axis in training: every weight's d_model-like
    dim additionally sharded so params+grads+Adam moments scale 1/(data·model)
    (the pod axis stays pure DP — cross-pod FSDP would gather over slow ICI).
  * Divisibility rule: a dim is sharded only if its size divides the axis
    size; otherwise replicated (e.g. gemma-2b's 8 heads on a 16-way model
    axis stay replicated, its 16384 FFN shards).

Serving caches: KV slots shard batch over data, heads over model when
divisible else the *retained-length* axis over model (engaging idle TP
capacity for decode); long-context (batch=1) shards retained length over
every axis — the sequence-parallel sparse decode of DESIGN.md §4.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.configs.base import ModelConfig
from repro.jax_compat import P
from repro.launch.mesh import axis_size, data_axes


def kernel_partition_plan(cfg: ModelConfig, serve) -> dict:
    """Per-shard partition plan for the Pallas hot paths on a model axis.

    Returns ``{dim_name: shard_count}`` for every kernel dimension the serve
    config enables — varlen attention shards query AND KV heads, the SSD
    scan shards state heads, the fused argmax shards the vocab — under
    ``serve.mesh_model``-way tensor parallelism. Raises ValueError naming
    every genuinely indivisible dimension. There is NO silent fallback: a
    (heads, vocab) × mesh combination either dispatches per-shard or the
    engine refuses to start (the kernel wrappers in ``kernels.ops`` enforce
    the same law at trace time).

    Pure arithmetic over the configs — no mesh or devices needed, so the
    engine can validate before building its mesh."""
    m = serve.mesh_model
    plan, bad = {}, []

    def need(dim: str, n: int) -> None:
        if m > 1 and n % m:
            bad.append(f"{dim}={n}")
        else:
            plan[dim] = m

    if serve.use_flash_kernel:
        if cfg.has_attention:
            need("n_heads", cfg.n_heads)
            need("n_kv_heads", cfg.n_kv_heads)
        if cfg.ssm_state:
            need("ssm_heads", cfg.ssm_heads)
    if serve.logit_mode == "fused":
        need("vocab_size", cfg.vocab_size)
    if bad:
        raise ValueError(
            "Pallas kernel paths cannot partition over the "
            f"{m}-way model axis: {', '.join(bad)} must divide it exactly "
            "(use a divisible mesh, or the jnp paths — "
            "use_flash_kernel=False / logit_mode='chunked')")
    return plan


class Rules:
    def __init__(self, cfg: ModelConfig, mesh, train: bool):
        self.cfg = cfg
        self.mesh = mesh
        self.train = train
        self.m = axis_size(mesh, "model")
        self.d = axis_size(mesh, "data")
        self.dp = data_axes(mesh)             # ('pod','data') or ('data',)

    def div(self, n: int, axis: str = "model") -> Optional[str]:
        sz = axis_size(self.mesh, axis)
        return axis if n and n % sz == 0 and n >= sz else None

    def fsdp(self, n: int) -> Optional[str]:
        if not self.train:
            return None
        return "data" if n % self.d == 0 and n >= self.d else None

    def fsdp_always(self, n: int) -> Optional[str]:
        """Storage sharding applied even at serve time (MoE expert stacks:
        qwen3-235b would need 29 GiB/chip under TP-only)."""
        return "data" if n % self.d == 0 and n >= self.d else None

    # ------------------------------------------------------------------
    def leaf_spec(self, path: str, shape) -> P:
        cfg = self.cfg
        name = path.split("/")[-1]
        D, V = cfg.d_model, cfg.vocab_size
        H, K = cfg.n_heads, cfg.n_kv_heads
        F, E = cfg.d_ff, cfg.n_experts
        Hs = cfg.ssm_heads if cfg.ssm_state else 0

        def pad(*trailing):
            lead = len(shape) - len(trailing)
            return P(*([None] * lead), *trailing)

        if name == "table":
            return pad(self.div(V), self.fsdp(D))
        if name == "lm_head":
            return pad(self.fsdp(D), self.div(V))
        if name == "wq":
            return pad(self.fsdp(D), self.div(H), None)
        if name in ("wk", "wv"):
            return pad(self.fsdp(D), self.div(K), None)
        if name == "bq":
            return pad(self.div(H), None)
        if name in ("bk", "bv"):
            return pad(self.div(K), None)
        if name == "wo":
            return pad(self.div(H), None, self.fsdp(D))
        if name in ("w_gate", "w_up"):
            if E and len(shape) >= 3 and shape[-3] == E:
                return pad(self.div(E), self.fsdp_always(D), None)
            return pad(self.fsdp(D), self.div(F))
        if name == "w_down":
            if E and len(shape) >= 3 and shape[-3] == F:
                return pad(self.div(E), None, self.fsdp_always(D))
            return pad(self.div(F), self.fsdp(D))
        if name == "w_z":
            inner = self.div(cfg.d_inner) if Hs and Hs % self.m == 0 else None
            return pad(self.fsdp(D), inner)
        if name in ("w_xbc", "w_dt"):
            return pad(self.fsdp(D), None)
        if name == "out_proj":
            inner = self.div(cfg.d_inner) if Hs and Hs % self.m == 0 else None
            return pad(inner, self.fsdp(D))
        if name == "proj":   # modality frontend
            return pad(None, self.fsdp(D))
        return P(*([None] * len(shape)))   # norms, scalars, conv, router

    # ------------------------------------------------------------------
    def params(self, params_shape) -> dict:
        def spec(path, leaf):
            keys = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
            return self.leaf_spec(keys, leaf.shape)
        return jax.tree_util.tree_map_with_path(spec, params_shape)

    def opt_state(self, params_shape) -> dict:
        """ZeRO-1: Adam moments follow param sharding (FSDP already shards
        them over data in train mode); step counter replicated."""
        ps = self.params(params_shape)
        return {"m": ps, "v": ps, "step": P()}

    # -- batches ----------------------------------------------------------
    def tokens(self, batch: int) -> P:
        if batch % axis_size(self.mesh, self.dp) == 0:
            return P(self.dp, None)
        return P(None, None)

    def frontend(self) -> P:
        return P(self.dp, None, None)

    # -- serving cache ------------------------------------------------------
    def _divisible_axes(self, n: int, axes: tuple) -> tuple:
        """Greedy prefix of ``axes`` whose combined size divides ``n``.

        Sharding is only legal on exact divisions (jax rejects uneven
        shards), so each candidate axis is kept only while the accumulated
        shard count still divides the dim — e.g. retain=96 on a ('data',
        'model') = (2, 64) request drops 'model' and shards over data only.
        """
        kept, prod = [], 1
        for a in axes:
            sz = prod * axis_size(self.mesh, a)
            if n % sz == 0 and n >= sz:
                kept.append(a)
                prod = sz
        return tuple(kept)

    def packed_kv(self, batch: int, retain: int, *,
                  data_parallel: bool = True,
                  slot_data_parallel: bool = False) -> object:
        """PackedKV specs: [L, B, K, R, dh] (+pos/valid [L, B, K, R]).

        ``data_parallel=False`` keeps the data axis out entirely (batch AND
        retained length): the serving engine's *streams* use this — every
        gathered sub-batch and every fresh Refresh cache regardless of its
        batch size (only the model axis shards within a slot).

        ``slot_data_parallel=True`` (with ``data_parallel=False``) addition-
        ally shards the SLOT axis over data — the engine's pool layout: a
        (d, m) mesh stores each data replica's slots locally, so pool bytes
        per device drop 1/d and ``plan_memory`` bills d replica streams.
        The engine pads the pool's slot count up to a data-axis multiple so
        the division is always exact."""
        from repro.models.sparse_select import PackedKV
        cfg = self.cfg
        dpn = axis_size(self.mesh, self.dp)
        if not data_parallel:
            seq_axes = ()
            b_ax = self.dp if (slot_data_parallel and batch % dpn == 0
                               and batch >= dpn) else None
        elif batch % dpn == 0 and batch >= dpn:
            b_ax, seq_axes = self.dp, ()
        else:
            b_ax, seq_axes = None, self.dp    # batch=1: sequence parallelism
        k_ax = self.div(cfg.n_kv_heads)
        r_axes = tuple(seq_axes)
        if k_ax is None:
            r_axes = r_axes + ("model",)      # engage idle TP on retained len
        r_axes = self._divisible_axes(retain, r_axes)
        r_ax = r_axes if r_axes else None
        kv = P(None, b_ax, k_ax, r_ax, None)
        meta = P(None, b_ax, k_ax, r_ax)
        return PackedKV(k=kv, v=kv, pos=meta, valid=meta)

    def ssm_cache(self, batch: int, *, data_parallel: bool = True,
                  slot_data_parallel: bool = False) -> object:
        from repro.models.ssm import SSMCache
        cfg = self.cfg
        dpn = axis_size(self.mesh, self.dp)
        b_ax = self.dp if (data_parallel or slot_data_parallel) \
            and batch % dpn == 0 and batch >= dpn else None
        h_ax = self.div(cfg.ssm_heads)
        return SSMCache(state=P(None, b_ax, h_ax, None, None),
                        conv=P(None, b_ax, None, None))

    def hybrid_cache(self, batch: int, retain: int, *,
                     data_parallel: bool = True,
                     slot_data_parallel: bool = False) -> object:
        from repro.models.hybrid import HybridCache
        sc = self.ssm_cache(batch, data_parallel=data_parallel,
                            slot_data_parallel=slot_data_parallel)
        return HybridCache(ssm_state=sc.state, conv=sc.conv,
                           kv=self.packed_kv(
                               batch, retain, data_parallel=data_parallel,
                               slot_data_parallel=slot_data_parallel))

    def cache(self, batch: int, retain: int, *, data_parallel: bool = True,
              slot_data_parallel: bool = False):
        fam = self.cfg.family
        if fam == "ssm":
            return self.ssm_cache(batch, data_parallel=data_parallel,
                                  slot_data_parallel=slot_data_parallel)
        if fam == "hybrid":
            return self.hybrid_cache(batch, retain,
                                     data_parallel=data_parallel,
                                     slot_data_parallel=slot_data_parallel)
        return self.packed_kv(batch, retain, data_parallel=data_parallel,
                              slot_data_parallel=slot_data_parallel)

    # ------------------------------------------------------------------
    def named(self, spec_tree):
        from repro.jax_compat import named_shardings
        return named_shardings(self.mesh, spec_tree)
