import os
if "XLA_FLAGS" not in os.environ:   # honor a user-exported XLA_FLAGS as-is
    os.environ["XLA_FLAGS"] = os.environ.get(
        "REPRO_XLA_FLAGS", "--xla_force_host_platform_device_count=2")

# --- 1-device-vs-N-device serving agreement (the sharding oracle) ----------
#
# The two lines above run before ANY other import (jax locks the device count
# on first init) — same precedent as the dry-run cells. This harness serves
# the SAME trace twice inside one process:
#
#   1. reference: the single-device engine (no mesh — the bit-identical
#      anchor of every padded-vs-packed oracle),
#   2. candidate: the identical engine under a REPRO_MESH device mesh
#      (params placed by Rules.params, slot pool sharded by Rules.cache,
#      vocab-parallel logit stage),
#
# and demands agreement on the three things that define serving correctness:
# committed token ids (exact), the captured slot-pool caches (allclose — TP
# all-reduces legally reorder float sums), and the final EngineStats token
# counters (exact: identical iteration plans must execute identical token
# geometry). All requests arrive at t=0 so planning depends only on
# budget/slot state, never on the clock — the two runs schedule identically
# by construction and any divergence is a sharding bug, not timing noise.
#
# Usage (CPU, 2 host devices):
#   XLA_FLAGS=--xla_force_host_platform_device_count=2 REPRO_MESH=1,2 \
#       python -m repro.launch.shard_check --arch llada-8b
#
# Exit code 0 + {"ok": true} JSON on agreement; non-zero otherwise.

import argparse
import dataclasses
import json

import numpy as np

from repro.configs import ARCHS, reduced
from repro.configs.base import ServeConfig
from repro.core.engine import Engine
from repro.launch.mesh import parse_mesh_env

COUNTERS = ("committed_tokens", "iterations", "refresh_steps", "reuse_steps",
            "refresh_tokens_real", "refresh_tokens_exec",
            "reuse_tokens_real", "reuse_tokens_exec",
            "logit_tokens_real", "logit_tokens_exec")


def serve_trace(cfg, serve, n: int, seed: int, warmup: bool,
                duplicate: bool = False):
    eng = Engine(cfg, serve, seed=seed)
    if warmup:
        eng.warmup()
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size - 1, int(rng.integers(8, 48)))
               for _ in range(n)]
    if duplicate:
        # pair requests onto identical prompts so content-addressed sharing
        # engages; the rng stream is drawn in full first, so the duplicated
        # trace differs from the unique one only by the aliasing
        prompts = [prompts[i // 2] for i in range(n)]
    reqs = [eng.submit(p, gen_len=16, arrival=0.0, rid=i)
            for i, p in enumerate(prompts)]
    stats = eng.run()
    return eng, reqs, stats


def check(arch: str, mesh_shape, n: int = 5, seed: int = 0,
          varlen: bool = True, warmup: bool = False,
          kernels: bool = False, sharing: bool = False) -> dict:
    import jax
    cfg = reduced(ARCHS[arch])
    serve = ServeConfig(
        max_num_batched_tokens=512, max_num_logits=64, block_size=8,
        steps_per_block=8, max_seq_len=128, max_slots=8,
        max_refresh_per_iter=2, logit_mode="chunked",
        varlen_pack=varlen, token_bucket=64, prefix_sharing=sharing)
    if kernels:
        # Pallas hot paths on BOTH runs: the reference is the 1-device
        # kernel run, so agreement proves the shard_mapped kernels (not a
        # jnp fallback) reproduce it bit-for-bit on token ids
        serve = dataclasses.replace(serve, use_flash_kernel=True,
                                    logit_mode="fused")
    # reference FIRST: the sharding policy a mesh engine installs must not
    # retroactively touch the single-device anchor. Under --sharing both
    # runs serve duplicated prompts, so agreement additionally proves the
    # refcounted pool (dedup hits, COW promotes, promote-on-release target
    # choice) is device-count invariant.
    eng_ref, r_ref, st_ref = serve_trace(cfg, serve, n, seed, warmup=False,
                                         duplicate=sharing)
    mesh_serve = dataclasses.replace(serve, mesh_shape=tuple(mesh_shape))
    eng, r_mesh, st_mesh = serve_trace(cfg, mesh_serve, n, seed,
                                       warmup=warmup, duplicate=sharing)
    out = dict(arch=arch, varlen=varlen, mesh=list(mesh_shape),
               mesh_devices=eng.mesh_devices, n=n, kernels=kernels,
               kernels_active=eng.kernels_active, sharing=sharing,
               shared_hits=st_mesh.shared_hits,
               shared_cow_promotes=st_mesh.shared_cow_promotes,
               ok=True, diffs=[])
    if sharing:
        for name in ("shared_hits", "shared_cow_promotes",
                     "phys_slots_peak"):
            va, vb = getattr(st_ref, name), getattr(st_mesh, name)
            if va != vb:
                out["diffs"].append(f"stats.{name}: {va} != {vb}")
        if st_mesh.shared_hits == 0:
            out["diffs"].append("sharing requested but no dedup hits — "
                                "the check proved nothing")
    if eng.mesh_devices != int(np.prod(mesh_shape)):
        out["diffs"].append("mesh collapsed to "
                            f"{eng.mesh_devices} devices")
    for a, b in zip(r_ref, r_mesh):
        if not np.array_equal(a.output_tokens(), b.output_tokens()):
            out["diffs"].append(f"token ids diverge on rid={a.rid}")
    for name in COUNTERS:
        va, vb = getattr(st_ref, name), getattr(st_mesh, name)
        if va != vb:
            out["diffs"].append(f"stats.{name}: {va} != {vb}")
    # captured caches: compare the slot pools leaf-by-leaf. A data-sharded
    # candidate pool may carry padded tail slots (so its slot axis divides
    # the data axis); they are never written — compare the common
    # real+scratch slot range only.
    ref_pool = jax.device_get(eng_ref.pool.cache)
    mesh_pool = jax.device_get(eng.pool.cache)
    ns = eng_ref.serve.max_slots + 1
    for i, (la, lb) in enumerate(zip(jax.tree.leaves(ref_pool),
                                     jax.tree.leaves(mesh_pool))):
        la, lb = la[:, :ns], lb[:, :ns]
        if la.shape != lb.shape:
            out["diffs"].append(f"pool leaf {i} shape {la.shape}!={lb.shape}")
        elif not np.allclose(np.asarray(la, np.float32),
                             np.asarray(lb, np.float32),
                             atol=1e-5, rtol=1e-5):
            err = float(np.abs(np.asarray(la, np.float32)
                               - np.asarray(lb, np.float32)).max())
            out["diffs"].append(f"pool leaf {i} max err {err:.2e}")
    out["ok"] = not out["diffs"]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llada-8b")
    ap.add_argument("--mesh", default=None,
                    help="'d,m' (default: REPRO_MESH, else 1,2)")
    ap.add_argument("--n", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--padded", action="store_true",
                    help="check the padded-oracle path instead of packed")
    ap.add_argument("--warmup", action="store_true",
                    help="AOT-warm the mesh engine first (audits sharded "
                         "warmup buckets too)")
    ap.add_argument("--kernels", action="store_true",
                    help="Pallas hot paths on both runs (use_flash_kernel + "
                         "logit_mode=fused): proves the shard_mapped "
                         "kernels match the 1-device kernel run")
    ap.add_argument("--sharing", action="store_true",
                    help="refcounted prefix sharing on both runs over "
                         "duplicated prompts: proves the ledger (hits, COW "
                         "promotes) is device-count invariant")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    mesh = (tuple(int(x) for x in args.mesh.split(","))
            if args.mesh else (parse_mesh_env() or (1, 2)))
    res = check(args.arch, mesh, n=args.n, seed=args.seed,
                varlen=not args.padded, warmup=args.warmup,
                kernels=args.kernels, sharing=args.sharing)
    print(json.dumps(res, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=2)
    return 0 if res["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
