"""Training launcher: masked-diffusion training with the fault-tolerant loop.

CPU example (reduced config, a few steps):
  PYTHONPATH=src python -m repro.launch.train --arch llada-8b --reduced \
      --steps 20 --global-batch 4 --seq-len 64

On a real mesh the same entry point shards params/opt per
``launch.sharding.Rules`` (see ``--mesh``); the dry-run driver
(``launch.dryrun``) is the no-hardware variant used in this container.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.configs import get_config, reduced
from repro.configs.base import TrainConfig
from repro.data.pipeline import synthetic_batch
from repro.train.train_loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llada-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    tc = TrainConfig(microbatches=args.microbatches, learning_rate=args.lr,
                     grad_compression=args.grad_compression,
                     loss_chunk=min(2048, args.global_batch * args.seq_len))
    os.makedirs(args.ckpt_dir, exist_ok=True)
    tr = Trainer(cfg, tc, args.ckpt_dir, args.global_batch, args.seq_len,
                 seed=args.seed, total_steps=max(args.steps, 100),
                 ckpt_every=args.ckpt_every)
    if tr.start_step:
        print(f"resumed from checkpoint at step {tr.start_step}")
    data = lambda s: synthetic_batch(cfg, args.global_batch, args.seq_len, s,
                                     seed=args.seed)
    logs = tr.run(args.steps, data, quiet=False)
    print(json.dumps({"final_loss": logs[-1]["loss"],
                      "steps": tr.start_step,
                      "stragglers": len(tr.events.stragglers),
                      "checkpoints": tr.events.checkpoints}))


if __name__ == "__main__":
    main()
