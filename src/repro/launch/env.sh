#!/usr/bin/env bash
# Host profile for serving runs: wrap any launcher command to get a
# reproducible host environment (docs/benchmarks.md "Host profile").
#
#   src/repro/launch/env.sh python -m repro.launch.serve --arch llada-8b ...
#   REPRO_HOST_DEVICES=4 src/repro/launch/env.sh python -m benchmarks.run ...
#
# Everything here is a host-side knob, not a numerics knob: result JSONs
# record host_profile=1 (serve.py reads REPRO_HOST_PROFILE) so benchmark
# diffs can refuse to compare profiled against unprofiled runs, but token
# output is bit-identical either way.
set -euo pipefail

# --- allocator -------------------------------------------------------------
# The pipelined engine's host side is allocation-heavy (per-iteration plan +
# pack buffers built while the device runs). tcmalloc's thread caches cut the
# malloc tail; probe the usual locations and silently keep glibc malloc when
# absent (the container does not ship it).
for _tc in /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
           /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
           /usr/lib/libtcmalloc_minimal.so.4; do
  if [[ -e "${_tc}" ]]; then
    export LD_PRELOAD="${_tc}${LD_PRELOAD:+:${LD_PRELOAD}}"
    # only giant allocations are worth a report line (default warns at 1GiB
    # and the packed KV pool legitimately allocates bigger arenas)
    export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=$((8 << 30))
    break
  fi
done

# --- XLA / jax -------------------------------------------------------------
# Step markers bracket each dispatched iteration in device traces so the
# wall-clock mode's overlap_frac can be cross-checked against a profile.
_xla="--xla_cpu_enable_xprof_traceme=true"
# CPU repro of an N-device mesh: REPRO_HOST_DEVICES=N splits the host into
# N XLA devices (the same flag the mesh docs tell you to set by hand).
if [[ -n "${REPRO_HOST_DEVICES:-}" ]]; then
  _xla+=" --xla_force_host_platform_device_count=${REPRO_HOST_DEVICES}"
fi
export XLA_FLAGS="${_xla}${XLA_FLAGS:+ ${XLA_FLAGS}}"

# Pin default dtypes: fp32/int32 everywhere, no x64 promotion — the modeled
# clock and the packed layouts assume 32-bit widths, and an ambient
# JAX_ENABLE_X64 would silently double every buffer in the footprint ledger.
export JAX_ENABLE_X64=0
export JAX_DEFAULT_DTYPE_BITS=32

# Keep TF/XLA's C++ logging out of benchmark stdout (JSON goes there).
export TF_CPP_MIN_LOG_LEVEL=${TF_CPP_MIN_LOG_LEVEL:-4}

# Mark the run so result JSONs can assert the profile was active.
export REPRO_HOST_PROFILE=1

exec "$@"
