"""AST invariant linter: framework, pragma handling, and the allowlist.

Rules (see :mod:`repro.analysis.rules`) are small classes that walk a parsed
module and yield :class:`Finding`\\ s. Two suppression channels, both explicit
and both carrying a justification:

* **Inline pragma** — ``# lint: allow(rule-name)`` on the offending line,
  with a neighbouring comment saying why. For point exemptions (e.g. the
  engine's single annotated host-sync point).
* **Allowlist** — :data:`ALLOWLIST` maps ``(rule, repo-relative path)`` to a
  one-line justification. For whole-file exemptions where the rule's concern
  is the file's *job* (the mesh factory uses the raw mesh API; the modeled
  clock is where modes get billed).

Suppressed findings are still collected (``LintReport.suppressed``) so the
JSON artifact shows what is being allowed and why.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

# ---------------------------------------------------------------------------
# allowlist: (rule, path) -> one-line justification
# ---------------------------------------------------------------------------
ALLOWLIST: Dict[Tuple[str, str], str] = {
    ("mesh-api", "src/repro/launch/mesh.py"):
        "the mesh factory: the one sanctioned home of jax.make_mesh",
    ("mesh-api", "src/repro/launch/dryrun.py"):
        "AOT compile harness places ShapeDtypeStructs with NamedSharding "
        "directly (no engine in the process)",
    ("host-sync", "src/repro/train/checkpoint.py"):
        "checkpoint save IS a deliberate full host transfer",
    ("silent-fallback", "src/repro/models/layers.py"):
        "kernel dispatch delegates to kernels.ops wrappers, which raise "
        "(_require_divisible) instead of falling back per shard",
    ("silent-fallback", "src/repro/models/sparse_select.py"):
        "kernel dispatch delegates to kernels.ops wrappers, which raise "
        "(_require_divisible) instead of falling back per shard",
    ("silent-fallback", "src/repro/models/ssm.py"):
        "kernel dispatch delegates to kernels.ops wrappers, which raise "
        "(_require_divisible) instead of falling back per shard",
    ("silent-fallback", "src/repro/models/hybrid.py"):
        "kernel dispatch delegates to kernels.ops wrappers, which raise "
        "(_require_divisible) instead of falling back per shard",
    ("silent-fallback", "src/repro/models/transformer.py"):
        "kernel dispatch delegates to kernels.ops wrappers, which raise "
        "(_require_divisible) instead of falling back per shard",
    ("silent-fallback", "src/repro/core/budgeting.py"):
        "budgeting IS the modeled clock: these branches are where each "
        "logit mode is billed differently",
}

# files the framework never scans: the doorway itself
SKIP_FILES = {"src/repro/jax_compat.py"}

_PRAGMA_RE = re.compile(r"#\s*lint:\s*allow\(([a-z0-9_-]+)\)")


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message}

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class FileContext:
    """One parsed module plus everything a rule needs to judge it."""
    path: str                      # repo-relative posix path
    source: str
    tree: ast.Module
    pragmas: Dict[int, Set[str]]   # line -> rule names allowed on that line
    imports_jax: bool = False
    parents: Dict[ast.AST, ast.AST] = field(default_factory=dict)

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self.parents.get(cur)
        return None


class Rule:
    """Base class: subclasses set ``name``/``description`` and yield
    findings from :meth:`check`. Registered in ``rules/__init__.py``."""
    name: str = ""
    description: str = ""

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, ctx: FileContext, node: ast.AST, msg: str) -> Finding:
        return Finding(self.name, ctx.path, getattr(node, "lineno", 0), msg)


@dataclass
class LintReport:
    findings: List[Finding] = field(default_factory=list)
    suppressed: List[dict] = field(default_factory=list)
    files_scanned: int = 0

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_dict(self) -> dict:
        return {"ok": self.ok, "files_scanned": self.files_scanned,
                "findings": [f.to_dict() for f in self.findings],
                "suppressed": self.suppressed}


def _dotted(node: ast.AST) -> Optional[str]:
    """``jax.sharding.PartitionSpec`` -> that string, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def build_context(path: Path, root: Path) -> Optional[FileContext]:
    rel = path.relative_to(root).as_posix()
    source = path.read_text()
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:                      # surfaced as a finding
        ctx = FileContext(rel, source, ast.Module(body=[], type_ignores=[]),
                          {})
        ctx.syntax_error = e                      # type: ignore[attr-defined]
        return ctx
    pragmas: Dict[int, Set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        for m in _PRAGMA_RE.finditer(line):
            pragmas.setdefault(i, set()).add(m.group(1))
    imports_jax = any(
        (isinstance(n, ast.Import)
         and any(a.name == "jax" or a.name.startswith("jax.")
                 for a in n.names))
        or (isinstance(n, ast.ImportFrom) and n.module
            and (n.module == "jax" or n.module.startswith("jax.")))
        for n in ast.walk(tree))
    parents: Dict[ast.AST, ast.AST] = {}
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            parents[child] = parent
    return FileContext(rel, source, tree, pragmas, imports_jax, parents)


def iter_source_files(root: Path) -> List[Path]:
    src = root / "src" / "repro"
    return sorted(p for p in src.rglob("*.py")
                  if p.relative_to(root).as_posix() not in SKIP_FILES)


def run_lint(root: Optional[Path] = None,
             rules: Optional[List[Rule]] = None) -> LintReport:
    """Lint ``<root>/src/repro`` with every registered rule."""
    from repro.analysis.rules import all_rules
    if root is None:
        # src/repro/analysis/lint.py -> repo root is four levels up
        root = Path(__file__).resolve().parents[3]
    rules = rules if rules is not None else all_rules()
    report = LintReport()
    for path in iter_source_files(root):
        ctx = build_context(path, root)
        report.files_scanned += 1
        err = getattr(ctx, "syntax_error", None)
        if err is not None:
            report.findings.append(Finding(
                "syntax", ctx.path, err.lineno or 0, str(err)))
            continue
        for rule in rules:
            for f in rule.check(ctx):
                allowed = ctx.pragmas.get(f.line, set())
                key = (f.rule, f.path)
                if f.rule in allowed:
                    report.suppressed.append(
                        {**f.to_dict(), "via": "pragma"})
                elif key in ALLOWLIST:
                    report.suppressed.append(
                        {**f.to_dict(), "via": "allowlist",
                         "justification": ALLOWLIST[key]})
                else:
                    report.findings.append(f)
    report.findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return report
