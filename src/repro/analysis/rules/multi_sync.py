"""multi-sync: at most ONE host sync per function, annotated or not.

The pipelined serving loop's contract (docs/engine.md) is ONE deferred
``jax.device_get`` per engine iteration — the ``host-sync`` rule makes each
sync explicit, but an annotated pragma on every line would still let a
function accumulate several "sanctioned" stalls. This rule counts sync
calls (``jax.device_get`` / ``block_until_ready`` / ``.item()``) per
enclosing function and flags every sync beyond the first, REGARDLESS of
``# lint: allow(host-sync)`` pragmas — the pragma names a different rule,
so it cannot suppress this one. Fixing a finding means restructuring to a
single batched transfer (tuple ``device_get``), not adding an annotation.

Scope mirrors ``host-sync``: launch/ and the analysis package are exempt by
path (printing results is their job). Whole-file exemptions go through the
ALLOWLIST under this rule's own name.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.lint import FileContext, Finding, Rule, _dotted
from repro.analysis.rules.host_sync import (_EXEMPT_PREFIXES, _SYNC_FUNCS,
                                            _SYNC_METHODS)


def _is_sync_call(node: ast.Call) -> bool:
    fn = node.func
    if not isinstance(fn, ast.Attribute):
        return False
    dotted = _dotted(fn)
    return dotted in _SYNC_FUNCS or (fn.attr in _SYNC_METHODS
                                     and dotted not in _SYNC_FUNCS)


class MultiSyncRule(Rule):
    name = "multi-sync"
    description = ("at most one host sync per function — a second "
                   "device_get/.item()/block_until_ready in the same "
                   "function is a pipeline stall even when each line is "
                   "individually annotated")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if ctx.path.startswith(_EXEMPT_PREFIXES):
            return
        by_scope = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) and _is_sync_call(node):
                scope = ctx.enclosing_function(node)
                by_scope.setdefault(scope, []).append(node)
        for scope, calls in by_scope.items():
            if len(calls) < 2:
                continue
            calls.sort(key=lambda n: (n.lineno, n.col_offset))
            where = (f"`{scope.name}`" if scope is not None
                     else "module scope")
            for extra in calls[1:]:
                yield self.finding(
                    ctx, extra,
                    f"{len(calls)} host syncs in {where} (first at line "
                    f"{calls[0].lineno}) — the serving loop's contract is "
                    "ONE deferred sync per iteration; batch the transfers "
                    "into a single tuple device_get")
