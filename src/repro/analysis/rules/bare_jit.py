"""bare-jit: no bare ``jax.jit`` outside ``repro.jax_compat``.

Every jit entry point compiles through ``jax_compat.jit`` /
``jax_compat.jit_sharded`` so the retrace sentinel can count compilations
(the wrapped Python body runs exactly once per jit-cache miss). A bare
``jax.jit`` is an uncounted compile: invisible to ``EngineStats`` and to the
zero-post-warmup budget the retrace test enforces.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.lint import FileContext, Finding, Rule, _dotted


class BareJitRule(Rule):
    name = "bare-jit"
    description = ("jax.jit only via jax_compat.jit/jit_sharded "
                   "(compile-counted entry points)")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Attribute) and _dotted(node) == "jax.jit":
                yield self.finding(
                    ctx, node,
                    "bare `jax.jit` — route through repro.jax_compat.jit "
                    "(or jit_sharded) so the compile is counted")
            elif (isinstance(node, ast.ImportFrom) and node.module == "jax"
                  and any(a.name == "jit" for a in node.names)):
                yield self.finding(
                    ctx, node,
                    "`from jax import jit` — route through "
                    "repro.jax_compat.jit so the compile is counted")
