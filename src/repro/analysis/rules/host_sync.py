"""host-sync: device→host transfers only at annotated sync points.

The serving engine's throughput story depends on the dispatch loop staying
async: exactly ONE host readback per iteration (the sampled ids/confidences,
``core/engine.py`` — carries the ``# lint: allow(host-sync)`` pragma). Any
other ``jax.device_get``/``block_until_ready``/``.item()`` — or a
``float()``/``bool()`` coercion of a device value — inside library code is a
hidden pipeline stall.

Scope: launch/ (CLI harnesses print results — syncing is their job) and the
analysis package itself are exempt by path; ``float()``/``bool()`` are only
flagged on bare-name arguments in jax-importing modules (attribute reads and
nested calls are overwhelmingly host-side config arithmetic).
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.lint import FileContext, Finding, Rule, _dotted

_EXEMPT_PREFIXES = ("src/repro/launch/", "src/repro/analysis/")
_SYNC_METHODS = ("item", "block_until_ready")
_SYNC_FUNCS = ("jax.device_get", "jax.block_until_ready")


class HostSyncRule(Rule):
    name = "host-sync"
    description = ("device→host syncs (.item, device_get, "
                   "block_until_ready, float()/bool() coercion) only at "
                   "annotated sync points")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if ctx.path.startswith(_EXEMPT_PREFIXES):
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if isinstance(fn, ast.Attribute):
                dotted = _dotted(fn)
                if dotted in _SYNC_FUNCS:
                    yield self.finding(
                        ctx, node,
                        f"`{dotted}` is a host sync — annotate the single "
                        "sync point with `# lint: allow(host-sync)` or keep "
                        "the value on device")
                    continue
                if fn.attr in _SYNC_METHODS and dotted not in _SYNC_FUNCS:
                    yield self.finding(
                        ctx, node,
                        f"`.{fn.attr}()` is a host sync — keep the value on "
                        "device or annotate the sync point")
            elif (isinstance(fn, ast.Name) and fn.id in ("float", "bool")
                  and ctx.imports_jax and len(node.args) == 1
                  and isinstance(node.args[0], ast.Name)):
                yield self.finding(
                    ctx, node,
                    f"`{fn.id}({node.args[0].id})` coerces a (potential) "
                    "device value to host — a hidden sync; annotate it or "
                    "keep the arithmetic on device")
