"""silent-fallback: every kernel-dispatch branch raises or is billed.

The honesty contract (ROADMAP invariants): a config that *asks* for the
Pallas hot paths (``use_flash_kernel``/``use_flash_refresh``/``use_kernel``/
``logit_mode``) either runs them, or the system raises — and whichever path
runs is billed as itself in the modeled clock. A branch on one of these
flags whose enclosing function neither raises nor touches a billing marker
(``_charge``, ``_require_divisible``, ``kernel_partition_plan``) is the
anatomy of a silent fallback: the flag flips behaviour with nothing keeping
the books straight.

The memory-footprint flags (``kv_quant``, ``prefix_sharing``) are held to
the same contract: a branch that quietly skips quantization or sharing
would under-bill capacity (``plan_memory`` converts both into slots), so
the enclosing function must raise or touch one of the quantization markers
(``quant_mask`` — the single billing/runtime leaf predicate — or
``dequantize_slot_leaves``).

Only ``if`` *statements* are examined — a ternary selecting a value is data
selection, not an execution-path fork.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.lint import FileContext, Finding, Rule

FLAGS = ("use_flash_kernel", "use_flash_refresh", "use_kernel", "logit_mode",
         "kv_quant", "prefix_sharing")
MARKERS = ("_charge", "_require_divisible", "kernel_partition_plan",
           "quant_mask", "quantize_slot_leaves", "dequantize_slot_leaves")


def _flags_in(test: ast.AST):
    hits = set()
    for n in ast.walk(test):
        if isinstance(n, ast.Attribute) and n.attr in FLAGS:
            hits.add(n.attr)
        elif isinstance(n, ast.Name) and n.id in FLAGS:
            hits.add(n.id)
    return hits


def _is_accounted(func: ast.AST) -> bool:
    """The enclosing function raises, or calls a billing marker, or IS one."""
    if getattr(func, "name", "") in MARKERS:
        return True
    for n in ast.walk(func):
        if isinstance(n, ast.Raise):
            return True
        if isinstance(n, ast.Call):
            fn = n.func
            callee = fn.attr if isinstance(fn, ast.Attribute) else \
                getattr(fn, "id", "")
            if callee in MARKERS:
                return True
    return False


class SilentFallbackRule(Rule):
    name = "silent-fallback"
    description = ("kernel-dispatch/memory-footprint flag branches must "
                   "raise or call a billing marker (_charge/"
                   "_require_divisible/kernel_partition_plan/quant_mask/"
                   "dequantize_slot_leaves)")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.If):
                continue
            hits = _flags_in(node.test)
            if not hits:
                continue
            func = ctx.enclosing_function(node)
            if func is not None and _is_accounted(func):
                continue
            yield self.finding(
                ctx, node,
                f"branch on {sorted(hits)} with no raise and no billing "
                "marker in the enclosing function — a silent kernel "
                "fallback (see docs/analysis.md)")
