"""Rule registry. Adding a rule = subclass :class:`repro.analysis.lint.Rule`
in a module here and list it in :data:`RULES` (docs/analysis.md walks
through it)."""
from repro.analysis.rules.bare_jit import BareJitRule
from repro.analysis.rules.donation import DonationRule
from repro.analysis.rules.host_sync import HostSyncRule
from repro.analysis.rules.mesh_api import MeshApiRule
from repro.analysis.rules.multi_sync import MultiSyncRule
from repro.analysis.rules.silent_fallback import SilentFallbackRule

RULES = [MeshApiRule, BareJitRule, HostSyncRule, MultiSyncRule,
         DonationRule, SilentFallbackRule]


def all_rules():
    return [cls() for cls in RULES]
