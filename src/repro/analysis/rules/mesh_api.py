"""mesh-api: the mesh/sharding API flows only through ``repro.jax_compat``.

The shim (``use_mesh``/``get_active_mesh``/``shard_map``/``jit_sharded``/
``named_shardings``/``P``) is the ONE doorway to jax's mesh machinery — it
absorbs the 0.4.x→0.5.x API churn and hosts the retrace counters. A module
that imports ``jax.sharding`` (or grabs ``jax.make_mesh``/``shard_map``)
directly bypasses both; it must either route through the shim or sit on the
allowlist with a justification (the mesh factory, the AOT dryrun harness).
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.lint import FileContext, Finding, Rule, _dotted

_BANNED_MODULES = ("jax.sharding", "jax.experimental.shard_map",
                   "jax.experimental.mesh_utils")
_BANNED_ATTRS = ("jax.make_mesh", "jax.set_mesh")


class MeshApiRule(Rule):
    name = "mesh-api"
    description = ("mesh/sharding API (jax.sharding, shard_map, make_mesh) "
                   "only via repro.jax_compat")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ImportFrom) and node.module:
                if any(node.module == m or node.module.startswith(m + ".")
                       for m in _BANNED_MODULES):
                    yield self.finding(
                        ctx, node,
                        f"direct `from {node.module} import ...` — use the "
                        "repro.jax_compat re-exports (e.g. `P`, `shard_map`)")
            elif isinstance(node, ast.Import):
                for alias in node.names:
                    if any(alias.name == m or alias.name.startswith(m + ".")
                           for m in _BANNED_MODULES):
                        yield self.finding(
                            ctx, node,
                            f"direct `import {alias.name}` — use "
                            "repro.jax_compat")
            elif isinstance(node, ast.Attribute):
                dotted = _dotted(node)
                if dotted is None:
                    continue
                if any(dotted == m or dotted.startswith(m + ".")
                       for m in _BANNED_MODULES) or dotted in _BANNED_ATTRS:
                    yield self.finding(
                        ctx, node,
                        f"direct `{dotted}` — use repro.jax_compat")
