"""donation: donated buffers must not be aliased or read after the call.

``jax_compat.jit(..., donate_argnums=...)`` hands an input buffer's storage
to XLA for reuse — after the call that Python array is DEAD. Two statically
checkable misuses:

* **aliasing** — the same variable passed at a donated position and any
  other position of the same call (``g(x, x)`` with arg 0 donated): XLA may
  overwrite the buffer while the other operand still reads it, or reject
  the donation silently — either way the caller's mental model is wrong.
* **use-after-donate** — the donated variable is *read* (Load) later in the
  same function. Re-binding (Store) is the idiomatic pattern
  (``buf = step(buf)``) and is safe.

The rule is intentionally local and name-based: it tracks only jitted
callables bound by a plain ``name = JC.jit(...)`` / ``jax_compat.jit(...)``
/ ``...jit_sharded(...)`` assignment in the same module, and only bare-Name
call arguments. The engine's dict-registered stage functions and the KV
pool's ``self._write`` are attribute/subscript-bound and therefore out of
scope here — their donation discipline is covered by the bit-identity tests
instead (tests/test_engine_pipeline.py).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.lint import FileContext, Finding, Rule, _dotted

_JIT_SPELLINGS = ("jit", "jit_sharded")
_COMPAT_MODULES = ("JC", "jax_compat")


def _donating_call(node: ast.AST) -> Optional[Tuple[ast.Call, object]]:
    """If ``node`` is a JC.jit/jit_sharded call with donate_argnums, return
    (call, donated-argnum-set-or-None). None = non-literal argnums: donated
    positions unknown, check aliasing against every position."""
    if not isinstance(node, ast.Call):
        return None
    dotted = _dotted(node.func)
    if dotted is None:
        return None
    parts = dotted.split(".")
    if len(parts) != 2 or parts[0] not in _COMPAT_MODULES \
            or parts[1] not in _JIT_SPELLINGS:
        return None
    for kw in node.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return node, {v.value}
        if isinstance(v, (ast.Tuple, ast.List)) and all(
                isinstance(e, ast.Constant) and isinstance(e.value, int)
                for e in v.elts):
            nums = {e.value for e in v.elts}
            return (node, nums) if nums else None
        return node, None
    return None


class DonationRule(Rule):
    name = "donation"
    description = ("buffers passed at donated argnums of a "
                   "jax_compat.jit(donate_argnums=...) callable must not "
                   "be aliased within the call or read after it")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        # pass 1: name -> donated argnum set for module-level-visible
        # `name = JC.jit(..., donate_argnums=...)` bindings.
        donors: Dict[str, Optional[Set[int]]] = {}
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            tgt = node.targets[0]
            if not isinstance(tgt, ast.Name):
                continue
            hit = _donating_call(node.value)
            if hit is not None:
                donors[tgt.id] = hit[1]
        if not donors:
            return

        # pass 2: judge every call of a donor.
        for call in ast.walk(ctx.tree):
            if not isinstance(call, ast.Call) \
                    or not isinstance(call.func, ast.Name) \
                    or call.func.id not in donors:
                continue
            argnums = donors[call.func.id]
            names_at: List[Optional[str]] = [
                a.id if isinstance(a, ast.Name) else None for a in call.args]
            donated: Dict[str, int] = {}
            for i, nm in enumerate(names_at):
                if nm is None:
                    continue
                if argnums is None or i in argnums:
                    donated.setdefault(nm, i)
            for nm, i in donated.items():
                dup = [j for j, other in enumerate(names_at)
                       if other == nm and j != i]
                if dup:
                    yield self.finding(
                        ctx, call,
                        f"`{nm}` is passed to `{call.func.id}` at donated "
                        f"position {i} and again at position {dup[0]} — "
                        "a donated buffer may be overwritten while the "
                        "aliased operand still reads it")
                    continue
                if nm in self._rebound_by(ctx, call):
                    continue      # `buf = step(buf)`: re-bound, safe
                use = self._first_use_after(ctx, call, nm)
                if use is not None:
                    yield Finding(
                        self.name, ctx.path, use.lineno,
                        f"`{nm}` is read after being donated to "
                        f"`{call.func.id}` (line {call.lineno}) — the "
                        "buffer is dead after the call; re-bind the "
                        "result or pass a copy")

    @staticmethod
    def _rebound_by(ctx: FileContext, call: ast.Call) -> Set[str]:
        """Names the statement containing ``call`` re-binds (its assignment
        targets): ``buf = step(buf)`` kills the old binding in the same
        statement, so later reads see the result, not the donated buffer."""
        node: ast.AST = call
        while node in ctx.parents and not isinstance(node, ast.stmt):
            node = ctx.parents[node]
        targets: List[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = list(node.targets)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        out: Set[str] = set()
        for t in targets:
            for n in ast.walk(t):
                if isinstance(n, ast.Name):
                    out.add(n.id)
        return out

    @staticmethod
    def _first_use_after(ctx: FileContext, call: ast.Call,
                         name: str) -> Optional[ast.Name]:
        """First occurrence of ``name`` in the enclosing scope strictly
        after the call, if it is a *read*. A Store first = safe re-bind."""
        scope = ctx.enclosing_function(call) or ctx.tree
        end = getattr(call, "end_lineno", call.lineno)
        best: Optional[ast.Name] = None
        for n in ast.walk(scope):
            if isinstance(n, ast.Name) and n.id == name and n.lineno > end:
                if best is None or (n.lineno, n.col_offset) < \
                        (best.lineno, best.col_offset):
                    best = n
        if best is not None and isinstance(best.ctx, ast.Load):
            return best
        return None
