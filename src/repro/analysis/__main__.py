"""CLI: ``python -m repro.analysis [--strict] [--grid-audit] [--json F]``.

Default runs the AST linter over ``src/repro``; ``--grid-audit`` adds the
abstract-trace sweep (every arch × serving mesh shape). ``--strict`` exits
non-zero on any finding/error (the CI gate); without it the run is
report-only. ``--json`` writes the combined findings artifact.
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="invariant analyzer: AST lint + abstract-trace grid "
                    "audit (docs/analysis.md)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any lint finding or audit error")
    ap.add_argument("--grid-audit", action="store_true",
                    help="also run the eval_shape grid audit "
                         "(arch x mesh sweep)")
    ap.add_argument("--archs", nargs="*", default=None,
                    help="restrict the grid audit to these archs")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="write the combined JSON findings artifact")
    args = ap.parse_args(argv)

    from repro.analysis.lint import run_lint
    report = run_lint()
    out = {"lint": report.to_dict()}
    print(f"lint: {report.files_scanned} files, "
          f"{len(report.findings)} finding(s), "
          f"{len(report.suppressed)} suppressed")
    for f in report.findings:
        print(f"  {f}")

    audit_ok = True
    if args.grid_audit:
        from repro.analysis.trace_audit import run_grid_audit
        audit = run_grid_audit(archs=args.archs)
        out["grid_audit"] = audit.to_dict()
        n_ok = sum(c.status == "ok" for c in audit.cells)
        n_raise = sum(c.status == "expected-raise" for c in audit.cells)
        print(f"grid audit: {len(audit.cells)} cells — {n_ok} ok, "
              f"{n_raise} expected-raise, {len(audit.errors)} error(s) "
              f"in {audit.elapsed_s:.1f}s")
        for c in audit.errors:
            print(f"  ERROR {c.arch} x {c.mesh}: {c.detail}")
        audit_ok = audit.ok

    if args.json:
        with open(args.json, "w") as fh:
            json.dump(out, fh, indent=2, sort_keys=True)
        print(f"wrote {args.json}")

    failed = not report.ok or not audit_ok
    if failed and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
