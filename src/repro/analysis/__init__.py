"""Static invariant analyzer: AST lint rules, grid audit, retrace sentinel.

Three layers, one CLI (``python -m repro.analysis``), run as a CI gate:

1. **AST invariant linter** (:mod:`repro.analysis.lint` +
   :mod:`repro.analysis.rules`): pluggable rules over ``src/repro`` that hold
   the codebase to the serving-system honesty contract — the mesh/sharding
   API flows only through ``repro.jax_compat``, no bare ``jax.jit``, host
   syncs only at annotated points, and no silent kernel→jnp fallbacks.
2. **Abstract-trace grid auditor** (:mod:`repro.analysis.trace_audit`):
   ``jax.eval_shape``-sweeps every jitted engine stage over all registered
   archs × serving mesh shapes, asserting each combo either traces with
   ``kernel_partition_plan``-consistent shapes or raises the documented
   divisibility error. No devices, CPU-fast.
3. **Retrace sentinel** (:mod:`repro.analysis.retrace`): audits an Engine's
   per-entry-point compile counters (``jax_compat.jit``/``jit_sharded``
   trace counters surfaced in ``EngineStats``) against a zero-post-warmup
   recompilation budget.

See ``docs/analysis.md`` for the rule catalogue and allowlist policy.
"""
from repro.analysis.lint import Finding, LintReport, run_lint  # noqa: F401
from repro.analysis.retrace import RetraceReport, check_engine  # noqa: F401
from repro.analysis.trace_audit import AuditReport, run_grid_audit  # noqa: F401
