"""Retrace sentinel: the zero-post-warmup recompilation budget.

Every jit entry point in the serving path compiles through
``jax_compat.jit``/``jit_sharded`` with an ``entry=`` tag: the wrapped
Python body runs exactly once per jit-cache miss, so incrementing a counter
inside it counts XLA compilations with no reliance on version-fragile
monitoring hooks. The Engine owns a per-instance counter (its stage jits +
the pool scatter/gather) and snapshots it in ``EngineStats``:

* ``compile_counts``   — per-entry totals (refresh/reuse/decode/pool_*),
* ``compiles_warmup``  — the count at the end of ``Engine.warmup()``,
* ``compiles_post_warmup`` — everything after; the budget this module
  holds at **zero** for the padded path (whose warmup doubling loops cover
  every pow2 bucket the runtime can request). The packed path warms only
  worst-case buckets AOT, so its budget is the lazily-compiled sub-bucket
  count — pass ``budget`` accordingly.

``check_engine(engine)`` is the post-run audit; the CI test
(``tests/test_analysis.py``) drives a full serve trace through it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List


@dataclass
class RetraceReport:
    compile_counts: Dict[str, int] = field(default_factory=dict)
    compiles_warmup: int = 0
    compiles_post_warmup: int = 0
    budget: int = 0
    violations: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self) -> dict:
        return {"ok": self.ok, "compile_counts": self.compile_counts,
                "compiles_warmup": self.compiles_warmup,
                "compiles_post_warmup": self.compiles_post_warmup,
                "budget": self.budget, "violations": self.violations}


def check_engine(engine, budget: int = 0) -> RetraceReport:
    """Audit an Engine's compile counters after a run.

    ``budget`` is the number of post-warmup compilations tolerated (0 for
    the padded path — its warmup covers every reachable bucket)."""
    stats = engine.stats
    report = RetraceReport(
        compile_counts=dict(stats.compile_counts),
        compiles_warmup=stats.compiles_warmup,
        compiles_post_warmup=stats.compiles_post_warmup,
        budget=budget)
    if stats.compiles_warmup == 0 and sum(stats.compile_counts.values()):
        report.violations.append(
            "warmup snapshot missing: Engine.warmup() was never called, so "
            "every compile is billed post-warmup")
    if report.compiles_post_warmup > budget:
        report.violations.append(
            f"{report.compiles_post_warmup} post-warmup compilation(s) "
            f"exceed the budget of {budget}: {report.compile_counts} "
            "(a steady-state retrace — an unwarmed bucket or an unstable "
            "jit cache key)")
    return report
