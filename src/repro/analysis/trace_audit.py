"""Abstract-trace grid auditor: every arch × serving mesh, no devices.

Three checks per ``(arch, mesh_shape)`` cell, all CPU-fast (<60s total):

1. **Partition-plan law** — ``kernel_partition_plan(full_cfg, serve)`` with
   the kernel-honest flags (``use_flash_kernel=True, logit_mode='fused'``):
   the cell either yields a plan (every kernel dim divides the model axis)
   or raises the documented divisibility error. An *undocumented* exception
   is a failure.
2. **Rules divisibility walk** — generate the full param PartitionSpec tree
   over a :class:`SimMesh` of that shape (``jax.eval_shape`` of
   ``init_params`` supplies the leaf shapes; no arrays are built) and assert
   every sharded dim divides exactly by its mesh axes — the "jax rejects
   uneven shards" law, checked without jax ever seeing the mesh.
3. **Stage traces** — ``jax.eval_shape`` every jitted engine stage (refresh,
   refresh_packed, reuse, reuse_packed, decode, decode_packed) on a
   ``reduced()`` config with the warmup's exact dummy-input geometry.
   Abstract evaluation runs with no active mesh, so stage traces are
   mesh-independent and memoized per arch; the per-mesh sharding semantics
   are covered by checks 1–2.

``run_grid_audit()`` returns an :class:`AuditReport`; the CLI
(``python -m repro.analysis --grid-audit``) fails on any ``error`` cell.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.configs.base import ServeConfig, reduced
from repro.jax_compat import P
from repro.launch.mesh import SimMesh, axis_size
from repro.launch.sharding import Rules, kernel_partition_plan

MESH_SHAPES: Tuple[Tuple[int, int], ...] = ((1, 1), (1, 2), (2, 1), (2, 2))

# the documented divisibility error (launch/sharding.kernel_partition_plan)
_DOC_ERR = "cannot partition over the"


def _serve_for(mesh_shape: Tuple[int, int]) -> ServeConfig:
    """Kernel-honest serve knobs at audit geometry (tiny, CPU-traceable)."""
    return ServeConfig(max_seq_len=64, block_size=8, token_bucket=32,
                       max_slots=4, max_num_batched_tokens=512,
                       max_num_logits=64, vocab_tile=64,
                       use_flash_kernel=True, logit_mode="fused",
                       varlen_pack=True,
                       mesh_shape=None if mesh_shape == (1, 1)
                       else mesh_shape)


@dataclass
class AuditCell:
    arch: str
    mesh: Tuple[int, int]
    status: str                    # "ok" | "expected-raise" | "error"
    detail: str = ""
    plan: Optional[dict] = None

    def to_dict(self) -> dict:
        return {"arch": self.arch, "mesh": list(self.mesh),
                "status": self.status, "detail": self.detail,
                "plan": self.plan}


@dataclass
class AuditReport:
    cells: List[AuditCell] = field(default_factory=list)
    stage_shapes: Dict[str, dict] = field(default_factory=dict)
    elapsed_s: float = 0.0

    @property
    def errors(self) -> List[AuditCell]:
        return [c for c in self.cells if c.status == "error"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def to_dict(self) -> dict:
        return {"ok": self.ok, "elapsed_s": round(self.elapsed_s, 2),
                "cells": [c.to_dict() for c in self.cells],
                "stage_shapes": self.stage_shapes}


# ---------------------------------------------------------------------------
# check 2: Rules divisibility walk
# ---------------------------------------------------------------------------

def _param_shapes(cfg):
    from repro.models import backbone as BB
    return jax.eval_shape(partial(BB.init_params, cfg), jax.random.PRNGKey(0))


def _check_rules_divisibility(cfg, mesh: SimMesh, pshapes) -> List[str]:
    """Every sharded dim of every param spec must divide by its axes."""
    rules = Rules(cfg, mesh, train=False)
    specs = rules.params(pshapes)
    bad: List[str] = []

    def walk(path, leaf, spec):
        for dim, axes in zip(leaf.shape, tuple(spec)):
            if axes is None:
                continue
            axes = axes if isinstance(axes, tuple) else (axes,)
            n = 1
            for a in axes:
                n *= axis_size(mesh, a)
            if n and dim % n:
                bad.append(f"{path}: dim {dim} % {axes}={n} != 0")

    flat, _ = jax.tree_util.tree_flatten_with_path(pshapes)
    sflat, _ = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))
    for (kp, leaf), (_, spec) in zip(flat, sflat):
        walk(jax.tree_util.keystr(kp), leaf, spec)
    # serving cache layouts must generate (and divide) for the pool geometry
    serve = _serve_for((axis_size(mesh, "data"), axis_size(mesh, "model")))
    retain = min(serve.retained_len, serve.max_seq_len - serve.block_size)
    rules.cache(serve.max_slots + 1, retain, data_parallel=False)
    rules.cache(serve.max_slots + 1, retain, data_parallel=False,
                slot_data_parallel=True)
    rules.tokens(serve.max_slots)
    return bad


# ---------------------------------------------------------------------------
# check 3: eval_shape stage traces (mesh-independent, memoized per arch)
# ---------------------------------------------------------------------------

def _trace_stages(name: str) -> dict:
    """eval_shape all six engine stages with the warmup's dummy geometry."""
    from repro.models import backbone as BB
    from repro.models import lm_head as LM
    from repro.models import transformer as T

    cfg = reduced(get_config(name))
    serve = _serve_for((1, 1))
    S, Sb = serve.max_seq_len, serve.block_size
    F = cfg.frontend_len if cfg.frontend_dim else 0
    retain = min(serve.retained_len, S - Sb)
    ctx = T.ServeContext(
        block_size=Sb, retain=retain, kernel_size=serve.kernel_size,
        selection=serve.selection,
        q_chunk=min(T.L.DEFAULT_Q_CHUNK, S),
        use_flash_kernel=serve.use_flash_kernel, max_seq_len=S)
    sds = jax.ShapeDtypeStruct
    pshapes = _param_shapes(cfg)
    b = 2
    fe = sds((b, F, cfg.frontend_dim), jnp.float32) if F else None
    dt = jnp.dtype(cfg.dtype)
    shapes: dict = {}

    def rec(stage, out):
        flat, _ = jax.tree_util.tree_flatten_with_path(out)
        shapes[stage] = {jax.tree_util.keystr(kp): list(x.shape)
                         for kp, x in flat}

    # padded refresh: tokens [b, S], valid [b, F+S], block_start [b]
    ref = jax.eval_shape(
        lambda p, t, v, bs, f: BB.serve_refresh(p, cfg, t, bs, ctx,
                                                frontend=f, token_valid=v),
        pshapes, sds((b, S), jnp.int32), sds((b, F + S), jnp.bool_),
        sds((b,), jnp.int32), fe)
    rec("refresh", ref)
    # packed refresh: one ragged stream of tp tokens over b segments
    tp = -(-(b * (S + F)) // serve.token_bucket) * serve.token_bucket
    refp = jax.eval_shape(
        lambda p, ft, pos, seg, v, cu, sl, bs, f: BB.serve_refresh_packed(
            p, cfg, ft, pos, seg, v, cu, sl, bs, ctx, frontend=f),
        pshapes, sds((tp,), jnp.int32), sds((tp,), jnp.int32),
        sds((tp,), jnp.int32), sds((tp,), jnp.bool_), sds((b,), jnp.int32),
        sds((b,), jnp.int32), sds((b,), jnp.int32), fe)
    rec("refresh_packed", refp)
    # reuse consumes refresh's captured cache (shape-struct flows through)
    reu = jax.eval_shape(
        lambda p, t, pos, c: BB.serve_reuse(p, cfg, t, pos, c, ctx),
        pshapes, sds((b, Sb), jnp.int32), sds((b, Sb), jnp.int32), ref.cache)
    rec("reuse", reu)
    reup = jax.eval_shape(
        lambda p, t, pos, c: BB.serve_reuse_packed(p, cfg, t, pos, c, ctx),
        pshapes, sds((b * Sb,), jnp.int32), sds((b * Sb,), jnp.int32),
        refp.cache)
    rec("reuse_packed", reup)
    n = serve.max_num_logits
    dec = jax.eval_shape(
        lambda e, h: LM.decode_tokens(e, cfg, h,
                                      max_num_logits=serve.max_num_logits,
                                      mode=serve.logit_mode,
                                      vocab_tile=serve.vocab_tile),
        pshapes["embed"], sds((n, cfg.d_model), dt))
    rec("decode", dec)
    decp = jax.eval_shape(
        lambda e, h, v: LM.decode_tokens_packed(
            e, cfg, h, v, max_num_logits=serve.max_num_logits,
            mode=serve.logit_mode, vocab_tile=serve.vocab_tile),
        pshapes["embed"], sds((n, cfg.d_model), dt), sds((n,), jnp.bool_))
    rec("decode_packed", decp)
    # block-hidden sanity: refresh must hand the decode stage d_model rows
    for stage, out in (("refresh", ref), ("refresh_packed", refp),
                       ("reuse", reu), ("reuse_packed", reup)):
        bh = getattr(out, "block_hidden", out)
        bh = bh if hasattr(bh, "shape") else None
        if bh is not None and bh.shape[-1] != cfg.d_model:
            raise AssertionError(
                f"{name}/{stage}: hidden last dim {bh.shape[-1]} != "
                f"d_model {cfg.d_model}")
    return shapes


# ---------------------------------------------------------------------------
# the sweep
# ---------------------------------------------------------------------------

def run_grid_audit(archs: Optional[Sequence[str]] = None,
                   mesh_shapes: Sequence[Tuple[int, int]] = MESH_SHAPES,
                   trace_stages: bool = True) -> AuditReport:
    t0 = time.perf_counter()
    report = AuditReport()
    names = list(archs) if archs is not None else sorted(ARCHS)
    full_pshapes: dict = {}
    for name in names:
        full_cfg = get_config(name)
        full_pshapes[name] = _param_shapes(full_cfg)
        if trace_stages:
            try:
                report.stage_shapes[name] = _trace_stages(name)
            except Exception as e:  # a stage that cannot trace is an error
                report.cells.append(AuditCell(
                    name, (0, 0), "error", f"stage trace failed: {e!r}"))
                continue
        for mesh_shape in mesh_shapes:
            serve = _serve_for(mesh_shape)
            try:
                plan = kernel_partition_plan(full_cfg, serve)
            except ValueError as e:
                if _DOC_ERR in str(e):
                    report.cells.append(AuditCell(
                        name, mesh_shape, "expected-raise", str(e)))
                else:
                    report.cells.append(AuditCell(
                        name, mesh_shape, "error",
                        f"undocumented ValueError: {e}"))
                continue
            except Exception as e:
                report.cells.append(AuditCell(
                    name, mesh_shape, "error", f"unexpected: {e!r}"))
                continue
            try:
                bad = _check_rules_divisibility(
                    full_cfg, SimMesh(mesh_shape), full_pshapes[name])
            except Exception as e:
                report.cells.append(AuditCell(
                    name, mesh_shape, "error", f"Rules walk failed: {e!r}",
                    plan=plan))
                continue
            if bad:
                report.cells.append(AuditCell(
                    name, mesh_shape, "error",
                    "uneven shards: " + "; ".join(bad[:5]), plan=plan))
            else:
                report.cells.append(AuditCell(
                    name, mesh_shape, "ok", plan=plan))
    report.elapsed_s = time.perf_counter() - t0
    return report
