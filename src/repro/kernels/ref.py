"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_logit_argmax(h, w, *, softcap: float = 0.0):
    """h: [T, D]; w: [D, V] -> (ids [T] i32, conf [T] f32)."""
    # f32 accumulation to match the kernel's MXU preferred_element_type —
    # bf16-rounded logits would flip argmax winners on near-ties.
    z = jnp.einsum("td,dv->tv", h, w,
                   preferred_element_type=jnp.float32)
    if softcap:
        z = softcap * jnp.tanh(z / softcap)
    ids = jnp.argmax(z, axis=-1).astype(jnp.int32)
    conf = jnp.exp(jnp.max(z, -1) - jax.nn.logsumexp(z, -1))
    return ids, conf


def packed_flash_attention(q, k, v, mask, *, softcap: float = 0.0):
    """q: [B,K,R,dh]; k/v: [B,K,T,dh]; mask: [B,K,Sb,T] -> [B,K,R,dh]."""
    B, K, R, dh = q.shape
    Sb = mask.shape[2]
    g = R // Sb
    z = jnp.einsum("bkrd,bktd->bkrt", q, k).astype(jnp.float32) * dh ** -0.5
    if softcap:
        z = softcap * jnp.tanh(z / softcap)
    zm = z.reshape(B, K, Sb, g, -1)
    zm = jnp.where(mask[:, :, :, None, :], zm, -1e30)
    p = jax.nn.softmax(zm.reshape(B, K, R, -1), axis=-1)
    return jnp.einsum("bkrt,bktd->bkrd", p.astype(v.dtype), v)


def head_score(q, k):
    """q: [B,K,R,dh]; k: [B,K,S,dh] -> raw scores [B,K,S] f32."""
    z = jnp.einsum("bkrd,bksd->bkrs", q, k).astype(jnp.float32)
    return z.max(axis=2)
