"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_logit_argmax(h, w, *, softcap: float = 0.0):
    """h: [T, D]; w: [D, V] -> (ids [T] i32, conf [T] f32)."""
    # f32 accumulation to match the kernel's MXU preferred_element_type —
    # bf16-rounded logits would flip argmax winners on near-ties.
    z = jnp.einsum("td,dv->tv", h, w,
                   preferred_element_type=jnp.float32)
    if softcap:
        z = softcap * jnp.tanh(z / softcap)
    ids = jnp.argmax(z, axis=-1).astype(jnp.int32)
    conf = jnp.exp(jnp.max(z, -1) - jax.nn.logsumexp(z, -1))
    return ids, conf


def packed_flash_attention(q, k, v, mask, *, softcap: float = 0.0):
    """q: [B,K,R,dh]; k/v: [B,K,T,dh]; mask: [B,K,Sb,T] -> [B,K,R,dh]."""
    B, K, R, dh = q.shape
    Sb = mask.shape[2]
    g = R // Sb
    z = jnp.einsum("bkrd,bktd->bkrt", q, k).astype(jnp.float32) * dh ** -0.5
    if softcap:
        z = softcap * jnp.tanh(z / softcap)
    zm = z.reshape(B, K, Sb, g, -1)
    zm = jnp.where(mask[:, :, :, None, :], zm, -1e30)
    p = jax.nn.softmax(zm.reshape(B, K, R, -1), axis=-1)
    return jnp.einsum("bkrt,bktd->bkrd", p.astype(v.dtype), v)


def varlen_attention(q, k, v, seg, pos, valid, *, softcap: float = 0.0,
                     causal: bool = False, window: int = 0, is_local=False):
    """q: [T, H, dh]; k/v: [T, K, dh]; seg/pos: [T] i32; valid: [T] bool.

    Oracle only — materializes the full [T, T] mask the kernel never builds.
    """
    T, H, dh = q.shape
    K = k.shape[1]
    G = H // K
    qg = q.reshape(T, K, G, dh)
    z = jnp.einsum("tkgd,skd->kgts", qg, k).astype(jnp.float32) * dh ** -0.5
    if softcap:
        z = softcap * jnp.tanh(z / softcap)
    ok = (seg[:, None] == seg[None, :]) & valid[None, :]
    if causal:
        ok = ok & (pos[:, None] >= pos[None, :])
    if window:
        dist = jnp.abs(pos[:, None] - pos[None, :])
        ok = ok & jnp.where(jnp.asarray(is_local, bool), dist <= window, True)
    z = jnp.where(ok[None, None], z, -1e30)
    p = jax.nn.softmax(z, axis=-1).astype(v.dtype)
    out = jnp.einsum("kgts,skd->tkgd", p, v)
    return out.reshape(T, H, dh)


def head_score(q, k):
    """q: [B,K,R,dh]; k: [B,K,S,dh] -> raw scores [B,K,S] f32."""
    z = jnp.einsum("bkrd,bksd->bkrs", q, k).astype(jnp.float32)
    return z.max(axis=2)
