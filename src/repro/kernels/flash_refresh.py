"""Flash attention for the Refresh phase (full-sequence bidirectional).

The roofline baseline showed the Refresh-phase jnp attention writes its
``[*, q_chunk, S]`` f32 score tensors to HBM — 30.8 TB/device/step for
qwen2.5-14b×prefill_32k, 76% of the memory term. This kernel is the classic
2-D-grid flash forward: scores/probs never leave VMEM; online-softmax state
(m, s) is carried across KV tiles in revisited output blocks.

Grid ``(B, K, n_q, n_kv)`` (KV innermost). Per (batch, kv-head, q-tile):
  q rows = q_tile × G (GQA groups flattened), online accumulation over KV
  tiles, final normalization fused into the last KV step.

Masking: built in-kernel from position tiles — bidirectional (diffusion
default), optional causal, optional sliding window (gemma2 local layers via a
runtime ``is_local`` scalar), and a KV validity mask. No [S, S] bias ever
exists.

VMEM at (q_tile=256, G=8, dh=128, kv_tile=512): q 1 MB + k/v 2×0.5 MB +
acc f32 1 MB + scores 2 MB ≈ 5 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from jax.experimental import pallas as pl

from repro import jax_compat as JC


def _kernel(q_ref, k_ref, v_ref, qpos_ref, kpos_ref, kvalid_ref, loc_ref,
            o_ref, m_ref, s_ref,
            *, scale: float, softcap: float, g: int, causal: bool,
            window: int, n_kv: int):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        s_ref[...] = jnp.zeros_like(s_ref)

    q = q_ref[0, 0]            # [R, dh]  (R = q_tile * G)
    k = k_ref[0, 0]            # [Tk, dh]
    v = v_ref[0, 0]
    qp = qpos_ref[0]           # [q_tile]
    kp = kpos_ref[0]           # [Tk]
    kv = kvalid_ref[0]         # [Tk]

    z = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [R, Tk]
    if softcap:
        z = softcap * jnp.tanh(z / softcap)
    ok = kv[None, :]
    if causal:
        ok = ok & (qp[:, None] >= kp[None, :])
    if window:
        # is_local arrives as a runtime flag (gemma2 alternates per layer)
        loc = loc_ref[0]
        ok = ok & ((jnp.abs(qp[:, None] - kp[None, :]) <= window) | ~loc)
    # broadcast the [q_tile, Tk] mask over the G group heads
    R, Tk = z.shape
    zm = jnp.where(ok[:, None, :], z.reshape(R // g, g, Tk), -1e30)
    z = zm.reshape(R, Tk)

    m_old = m_ref[0, 0]
    m_new = jnp.maximum(m_old, jnp.max(z, axis=1))
    alpha = jnp.exp(m_old - m_new)
    p = jnp.exp(z - m_new[:, None])
    s_new = s_ref[0, 0] * alpha + jnp.sum(p, axis=1)
    o_new = (o_ref[0, 0] * alpha[:, None]
             + jnp.dot(p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32))
    m_ref[0, 0] = m_new
    s_ref[0, 0] = s_new

    @pl.when(j == n_kv - 1)
    def _final():
        o_ref[0, 0] = o_new / jnp.maximum(s_new, 1e-30)[:, None]

    @pl.when(j < n_kv - 1)
    def _accum():
        o_ref[0, 0] = o_new


@functools.partial(JC.jit, static_argnames=(
    "softcap", "causal", "window", "q_tile", "kv_tile", "interpret"))
def flash_refresh_call(
    q: jax.Array,        # [B, K, S*G, dh] row-flat GQA layout
    k: jax.Array,        # [B, K, S, dh]
    v: jax.Array,        # [B, K, S, dh]
    q_pos: jax.Array,    # [B, S] int32
    kv_pos: jax.Array,   # [B, S] int32
    kv_valid: jax.Array,  # [B, S] bool
    is_local: jax.Array,  # [1] bool (runtime: gemma2 alternating layers)
    *,
    softcap: float = 0.0,
    causal: bool = False,
    window: int = 0,
    q_tile: int = 256,
    kv_tile: int = 512,
    interpret: bool = True,
):
    B, K, RG, dh = q.shape
    S = k.shape[2]                 # KV length
    Sq = q_pos.shape[1]            # query length (may be a seq-shard of S)
    g = RG // Sq
    q_tile = min(q_tile, Sq)
    kv_tile = min(kv_tile, S)
    assert Sq % q_tile == 0 and S % kv_tile == 0, (Sq, S, q_tile, kv_tile)
    n_q, n_kv = Sq // q_tile, S // kv_tile
    kern = functools.partial(
        _kernel, scale=dh ** -0.5, softcap=softcap, g=g, causal=causal,
        window=window, n_kv=n_kv)
    out, m, s = pl.pallas_call(
        kern,
        grid=(B, K, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, q_tile * g, dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, kv_tile, dh), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, kv_tile, dh), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, q_tile), lambda b, h, i, j: (b, i)),
            pl.BlockSpec((1, kv_tile), lambda b, h, i, j: (b, j)),
            pl.BlockSpec((1, kv_tile), lambda b, h, i, j: (b, j)),
            pl.BlockSpec((1,), lambda b, h, i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q_tile * g, dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, q_tile * g), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, q_tile * g), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, K, RG, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, K, RG), jnp.float32),
            jax.ShapeDtypeStruct((B, K, RG), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, q_pos, kv_pos, kv_valid, is_local)
    return out
