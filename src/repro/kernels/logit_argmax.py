"""Fused online logit→token kernel — the TPU-native form of paper C1.

The paper's Logit Decomposition splits the output projection into serial
token-axis sub-batches and frees each ``[chunk, V]`` buffer before the next.
XLA has no ``free()``; the TPU-native equivalent is to *never materialize*
``[chunk, V]``: tile the vocabulary axis through VMEM and carry only the
O(chunk) online-argmax/online-softmax state across tiles. Peak activation for
the output stage drops from ``chunk × V × 2B`` (paper) to
``T_tile × V_tile × 4B`` (here) — e.g. for LLaDA-8B (V=126,464),
2048×126464×2B ≈ 494 MB → 256×512×4B ≈ 0.5 MB per core-step.

Grid: ``(T // T_tile, V // V_tile)`` — the V axis iterates innermost
(sequentially on a TPU core), accumulating into revisited output blocks:

  * ``m``   — running max logit           [T]
  * ``idx`` — running argmax index        [T]
  * ``s``   — running Σ exp(z − m)        [T]  (online softmax)

``conf = 1/s`` (softmax probability of the argmax) is formed in ``ops.py``.

MXU alignment: the matmul is ``[T_tile, D] × [D, V_tile]`` with T_tile, V_tile
multiples of 128 and D the full model dim (bf16-friendly; accumulation f32).
VMEM at defaults (T_tile=256, V_tile=512, D=8192): q-block 4 MB + w-block
8 MB + acc < 13 MB — under the 16 MB/core budget for the largest arch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from jax.experimental import pallas as pl

from repro import jax_compat as JC


def _kernel(h_ref, w_ref, valid_ref, idx_ref, m_ref, s_ref, *, softcap: float,
            v_tile: int, n_v: int, w_layout: str):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        s_ref[...] = jnp.zeros_like(s_ref)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    # whole-iteration packing: the hidden stream is token-bucketed, so a
    # trailing T-tile can be all bucket padding — skip its entire V loop
    # (the matmul never runs; outputs keep their init values and the wrapper
    # masks them). Within a mixed tile padding rows just ride along.
    @pl.when(jnp.any(valid_ref[...]))
    def _compute():
        h = h_ref[...]                 # [T_tile, D]
        w = w_ref[...]                 # [D, V_tile] ("dv") | [V_tile, D] ("vd")
        if w_layout == "vd":
            # tied-embedding layout: contract over the last dim of both — the
            # MXU takes either orientation; this avoids transposing the whole
            # [V, D] table in HBM.
            z = jax.lax.dot_general(h, w, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        else:
            z = jnp.dot(h, w, preferred_element_type=jnp.float32)  # [T_tile, V_tile]
        if softcap:
            z = softcap * jnp.tanh(z / softcap)

        local_m = jnp.max(z, axis=1)                           # [T_tile]
        local_i = jnp.argmax(z, axis=1).astype(jnp.int32) + j * v_tile

        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, local_m)
        s_ref[...] = (s_ref[...] * jnp.exp(m_old - m_new)
                      + jnp.sum(jnp.exp(z - m_new[:, None]), axis=1))
        idx_ref[...] = jnp.where(local_m > m_old, local_i, idx_ref[...])
        m_ref[...] = m_new


@functools.partial(JC.jit, static_argnames=("softcap", "t_tile", "v_tile",
                                             "interpret", "w_layout"))
def fused_logit_argmax_call(
    h: jax.Array,          # [T, D]
    w: jax.Array,          # [D, V] (w_layout="dv") or [V, D] ("vd", tied)
    valid: jax.Array,      # [T] bool (False on bucket-padding rows)
    *,
    softcap: float = 0.0,
    t_tile: int = 256,
    v_tile: int = 512,
    interpret: bool = True,
    w_layout: str = "dv",
):
    T, D = h.shape
    V = w.shape[1] if w_layout == "dv" else w.shape[0]
    t_tile = min(t_tile, T)
    v_tile = min(v_tile, V)
    assert T % t_tile == 0 and V % v_tile == 0, (T, t_tile, V, v_tile)
    n_t, n_v = T // t_tile, V // v_tile

    kern = functools.partial(_kernel, softcap=softcap, v_tile=v_tile, n_v=n_v,
                             w_layout=w_layout)
    w_spec = (pl.BlockSpec((D, v_tile), lambda i, j: (0, j))
              if w_layout == "dv"
              else pl.BlockSpec((v_tile, D), lambda i, j: (j, 0)))
    idx, m, s = pl.pallas_call(
        kern,
        grid=(n_t, n_v),
        in_specs=[
            pl.BlockSpec((t_tile, D), lambda i, j: (i, 0)),
            w_spec,
            pl.BlockSpec((t_tile,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((t_tile,), lambda i, j: (i,)),
            pl.BlockSpec((t_tile,), lambda i, j: (i,)),
            pl.BlockSpec((t_tile,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((T,), jnp.float32),
            jax.ShapeDtypeStruct((T,), jnp.float32),
        ],
        interpret=interpret,
    )(h, w, valid)
    return idx, m, s
