"""Ragged (varlen) flash attention over a token-packed stream.

The paper's flattened engine (§4.1) packs every Refresh request of an
iteration into one ragged ``[T_total, ...]`` token stream so compute scales
with *actual* tokens instead of ``batch_bucket × max_seq_len`` padding. This
kernel is the attention side of that contract: one flat stream, per-token
segment ids (request index, ascending; padding uses a large sentinel), and
in-kernel segment masking — a query attends to a key iff both tokens belong
to the same request. No cross-request attention, and no ``[S, S]`` bias is
ever materialized.

Grid ``(K, n_q, n_kv)`` (KV innermost), flash online-softmax accumulation as
in :mod:`flash_refresh`, plus a **tile-skip**: segment ids are ascending
along the stream, so a KV tile whose segment range does not intersect the
query tile's range is skipped entirely (only the init/normalize bookkeeping
runs). That is what makes packed-attention FLOPs track ``Σ S_i²`` rather
than ``T_total²`` at tile granularity.

Masking inputs are per-token 1-D arrays: ``pos`` (position *within* the
request — drives causal and sliding-window masks), ``seg`` (request id),
``valid`` (False on bucket padding). GQA rows are token-major flattened
(row = t·G + g) exactly like the refresh kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from jax.experimental import pallas as pl

from repro import jax_compat as JC

# Segment id for bucket-padding tokens. Must sort after every real request id
# so the ascending-stream tile-skip stays valid.
PAD_SEG = (1 << 30)


def _kernel(q_ref, k_ref, v_ref, qpos_ref, kpos_ref, qseg_ref, kseg_ref,
            kvalid_ref, loc_ref, o_ref, m_ref, s_ref,
            *, scale: float, softcap: float, g: int, causal: bool,
            window: int, n_kv: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        s_ref[...] = jnp.zeros_like(s_ref)

    qs = qseg_ref[...]             # [q_tile]
    ks = kseg_ref[...]             # [Tk]
    # tile-skip: streams are segment-ascending, so disjoint id ranges cannot
    # share a request — skip the matmul + softmax update entirely.
    overlap = (jnp.min(qs) <= jnp.max(ks)) & (jnp.min(ks) <= jnp.max(qs))

    @pl.when(overlap)
    def _compute():
        q = q_ref[0]               # [R, dh]  (R = q_tile * G)
        k = k_ref[0]               # [Tk, dh]
        v = v_ref[0]
        qp = qpos_ref[...]         # [q_tile]
        kp = kpos_ref[...]         # [Tk]
        kv = kvalid_ref[...]       # [Tk]

        z = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if softcap:
            z = softcap * jnp.tanh(z / softcap)
        ok = kv[None, :] & (qs[:, None] == ks[None, :])
        if causal:
            ok = ok & (qp[:, None] >= kp[None, :])
        if window:
            loc = loc_ref[0]
            ok = ok & ((jnp.abs(qp[:, None] - kp[None, :]) <= window) | ~loc)
        R, Tk = z.shape
        zm = jnp.where(ok[:, None, :], z.reshape(R // g, g, Tk), -1e30)
        z = zm.reshape(R, Tk)

        m_old = m_ref[0]
        m_new = jnp.maximum(m_old, jnp.max(z, axis=1))
        alpha = jnp.exp(m_old - m_new)
        p = jnp.exp(z - m_new[:, None])
        s_ref[0] = s_ref[0] * alpha + jnp.sum(p, axis=1)
        o_ref[0] = (o_ref[0] * alpha[:, None]
                    + jnp.dot(p.astype(v.dtype), v,
                              preferred_element_type=jnp.float32))
        m_ref[0] = m_new

    @pl.when(j == n_kv - 1)
    def _final():
        o_ref[0] = o_ref[0] / jnp.maximum(s_ref[0], 1e-30)[:, None]


@functools.partial(JC.jit, static_argnames=(
    "softcap", "causal", "window", "q_tile", "kv_tile", "interpret"))
def flash_varlen_call(
    q: jax.Array,         # [K, T*G, dh] row-flat GQA layout (token-major)
    k: jax.Array,         # [K, T, dh]
    v: jax.Array,         # [K, T, dh]
    pos: jax.Array,       # [T] int32 position within the owning request
    seg: jax.Array,       # [T] int32 ascending request id (PAD_SEG on pad)
    kv_valid: jax.Array,  # [T] bool
    is_local: jax.Array,  # [1] bool (gemma2 alternating local layers)
    *,
    softcap: float = 0.0,
    causal: bool = False,
    window: int = 0,
    q_tile: int = 256,
    kv_tile: int = 512,
    interpret: bool = True,
):
    K, RG, dh = q.shape
    T = k.shape[1]
    g = RG // T
    q_tile = min(q_tile, T)
    kv_tile = min(kv_tile, T)
    assert T % q_tile == 0 and T % kv_tile == 0, (T, q_tile, kv_tile)
    n_q, n_kv = T // q_tile, T // kv_tile
    kern = functools.partial(
        _kernel, scale=dh ** -0.5, softcap=softcap, g=g, causal=causal,
        window=window, n_kv=n_kv)
    out, m, s = pl.pallas_call(
        kern,
        grid=(K, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, q_tile * g, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, kv_tile, dh), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, kv_tile, dh), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((q_tile,), lambda h, i, j: (i,)),
            pl.BlockSpec((kv_tile,), lambda h, i, j: (j,)),
            pl.BlockSpec((q_tile,), lambda h, i, j: (i,)),
            pl.BlockSpec((kv_tile,), lambda h, i, j: (j,)),
            pl.BlockSpec((kv_tile,), lambda h, i, j: (j,)),
            pl.BlockSpec((1,), lambda h, i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, q_tile * g, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, q_tile * g), lambda h, i, j: (h, i)),
            pl.BlockSpec((1, q_tile * g), lambda h, i, j: (h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((K, RG, dh), jnp.float32),
            jax.ShapeDtypeStruct((K, RG), jnp.float32),
            jax.ShapeDtypeStruct((K, RG), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, pos, pos, seg, seg, kv_valid, is_local)
    return out


# ---------------------------------------------------------------------------
# cross-attention variant: packed block queries vs. per-segment retained KV
# (the Reuse phase of the whole-iteration packed pipeline)
# ---------------------------------------------------------------------------

def _cross_kernel(q_ref, k_ref, v_ref, qpos_ref, kpos_ref, qseg_ref,
                  kseg_ref, kvalid_ref, loc_ref, o_ref, m_ref, s_ref,
                  *, scale: float, softcap: float, g: int, causal: bool,
                  window: int, n_kv: int):
    """Like :func:`_kernel` but the query and KV streams are distinct: the
    queries are the iteration's packed active blocks (``[Tq]``, segment id =
    reuse-request index) and the KV stream is the per-request ``[retain+Sb]``
    slice of the slot pool (``[Tkv]``, same segment ids, per-KV-head
    positions/validity because head-centric selection retains a different
    token set per head). Both streams are segment-ascending, so the same
    range-disjointness tile-skip applies: a KV tile owned by other requests
    never reaches the MXU ("tile-skip over non-owned slots")."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        s_ref[...] = jnp.zeros_like(s_ref)

    qs = qseg_ref[...]             # [q_tile]
    ks = kseg_ref[...]             # [Tk]
    overlap = (jnp.min(qs) <= jnp.max(ks)) & (jnp.min(ks) <= jnp.max(qs))

    @pl.when(overlap)
    def _compute():
        q = q_ref[0]               # [R, dh]  (R = q_tile * G)
        k = k_ref[0]               # [Tk, dh]
        v = v_ref[0]
        qp = qpos_ref[...]         # [q_tile]
        kp = kpos_ref[0]           # [Tk]   (per KV head)
        kv = kvalid_ref[0]         # [Tk]   (per KV head)

        z = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if softcap:
            z = softcap * jnp.tanh(z / softcap)
        ok = kv[None, :] & (qs[:, None] == ks[None, :])
        if causal:
            ok = ok & (qp[:, None] >= kp[None, :])
        if window:
            loc = loc_ref[0]
            ok = ok & ((jnp.abs(qp[:, None] - kp[None, :]) <= window) | ~loc)
        R, Tk = z.shape
        zm = jnp.where(ok[:, None, :], z.reshape(R // g, g, Tk), -1e30)
        z = zm.reshape(R, Tk)

        m_old = m_ref[0]
        m_new = jnp.maximum(m_old, jnp.max(z, axis=1))
        alpha = jnp.exp(m_old - m_new)
        p = jnp.exp(z - m_new[:, None])
        s_ref[0] = s_ref[0] * alpha + jnp.sum(p, axis=1)
        o_ref[0] = (o_ref[0] * alpha[:, None]
                    + jnp.dot(p.astype(v.dtype), v,
                              preferred_element_type=jnp.float32))
        m_ref[0] = m_new

    @pl.when(j == n_kv - 1)
    def _final():
        o_ref[0] = o_ref[0] / jnp.maximum(s_ref[0], 1e-30)[:, None]


@functools.partial(JC.jit, static_argnames=(
    "softcap", "causal", "window", "q_tile", "kv_tile", "interpret"))
def flash_varlen_cross_call(
    q: jax.Array,          # [K, Tq*G, dh] row-flat GQA layout (token-major)
    k: jax.Array,          # [K, Tkv, dh]
    v: jax.Array,          # [K, Tkv, dh]
    q_pos: jax.Array,      # [Tq] int32 absolute position of each query token
    kv_pos: jax.Array,     # [K, Tkv] int32 per-head original token positions
    q_seg: jax.Array,      # [Tq] int32 ascending reuse-request id (PAD_SEG pad)
    kv_seg: jax.Array,     # [Tkv] int32 ascending owner id (head-independent)
    kv_valid: jax.Array,   # [K, Tkv] bool (False on unselected cache slots)
    is_local: jax.Array,   # [1] bool
    *,
    softcap: float = 0.0,
    causal: bool = False,
    window: int = 0,
    q_tile: int = 128,
    kv_tile: int = 512,
    interpret: bool = True,
):
    """Ragged cross-attention dispatch (bidirectional dLLM Reuse mask by
    default; ``causal=True`` for the hybrid family's causal shared block).

    Unlike :func:`flash_varlen_call` the query/KV streams differ in length
    and layout: Tq = Σ block tokens, Tkv = R·(retain + Sb) pool slices. KV
    positions and validity carry a leading KV-head axis because head-centric
    selection (C3) retains an independent token set per head.
    """
    K, RG, dh = q.shape
    Tq = q_pos.shape[0]
    Tkv = k.shape[1]
    g = RG // Tq
    q_tile = min(q_tile, Tq)
    kv_tile = min(kv_tile, Tkv)
    assert Tq % q_tile == 0 and Tkv % kv_tile == 0, (Tq, q_tile, Tkv, kv_tile)
    n_q, n_kv = Tq // q_tile, Tkv // kv_tile
    kern = functools.partial(
        _cross_kernel, scale=dh ** -0.5, softcap=softcap, g=g, causal=causal,
        window=window, n_kv=n_kv)
    out, m, s = pl.pallas_call(
        kern,
        grid=(K, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, q_tile * g, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, kv_tile, dh), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, kv_tile, dh), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((q_tile,), lambda h, i, j: (i,)),
            pl.BlockSpec((1, kv_tile), lambda h, i, j: (h, j)),
            pl.BlockSpec((q_tile,), lambda h, i, j: (i,)),
            pl.BlockSpec((kv_tile,), lambda h, i, j: (j,)),
            pl.BlockSpec((1, kv_tile), lambda h, i, j: (h, j)),
            pl.BlockSpec((1,), lambda h, i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, q_tile * g, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, q_tile * g), lambda h, i, j: (h, i)),
            pl.BlockSpec((1, q_tile * g), lambda h, i, j: (h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((K, RG, dh), jnp.float32),
            jax.ShapeDtypeStruct((K, RG), jnp.float32),
            jax.ShapeDtypeStruct((K, RG), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, q_pos, kv_pos, q_seg, kv_seg, kv_valid, is_local)
    return out
