"""Segment-reset SSD scan over a token-packed stream (Pallas).

The varlen side of the Mamba2 SSD recurrence: one ragged ``[T_total]`` token
stream carries every Refresh request of an iteration (delimited by
cu_seqlens; ``reset`` marks each request's first token) and the kernel runs
the chunked state-space scan with the recurrent state zeroed at every
segment boundary — the scan-family analogue of the segment-masked varlen
attention kernel. Compute stays the blocked SSD math (intra-chunk quadratic
term as MXU matmuls + an O(1)-state inter-chunk recurrence carried across
grid steps), so FLOPs scale with real tokens instead of the padded
``batch_bucket × max_seq_len`` rectangle.

Grid is 1-D over stream chunks (sequential — the state carry lives in an
output ref revisited by every step, like the flash kernels' accumulators).
Segment resets are handled by a *reset-count* mask, NOT by a −inf decay
injection: a pair (j → i) contributes iff no reset falls in ``(j, i]``
(``cnt[i] == cnt[j]`` for the inclusive reset prefix-count), which keeps the
decay cumsums free of sentinel values — a −1e30 sentinel would absorb every
subsequent f32 cumsum term and zero the post-reset decays entirely.

Per-request state capture happens **in-kernel**: ``cap_rows[r]`` names the
flat row after which request r's recurrent state must be read (−1 → zero
state, e.g. a block at position 0). The owning chunk computes the masked
partial state ``Σ_{j≤idx} exp(cs[idx]−cs[j])·b_j + gate·exp(cs[idx])·state``
and accumulates it into the ``[R, H, P, N]`` capture output — no
``[T, H, P, N]`` per-token state tensor is ever materialized (that is the
jnp associative-scan fallback's memory cost, see
:func:`repro.models.ssm.varlen_ssd_scan`).

Cumulative sums are computed as lower-triangular matmuls (MXU-friendly; no
reliance on ``cumsum`` lowering inside the kernel). All exponents are ≤ 0 on
unmasked lanes (dA = dt·A < 0), so nothing overflows where it matters;
masked lanes may hit ``inf`` before the ``where`` discards them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from jax.experimental import pallas as pl

from repro import jax_compat as JC


def _kernel(xdt_ref, dA_ref, b_ref, c_ref, reset_ref, cap_ref,
            y_ref, cap_out_ref, state_ref, *, c: int, r_cap: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)
        cap_out_ref[...] = jnp.zeros_like(cap_out_ref)

    xdt = xdt_ref[...]        # [c, H, P] f32  (x · dt)
    dA = dA_ref[...]          # [c, H]    f32  (dt · A, always < 0)
    Bm = b_ref[...]           # [c, N]    f32
    Cm = c_ref[...]           # [c, N]    f32
    rst = reset_ref[...]      # [c]       f32  (1.0 at segment starts)
    state_in = state_ref[...]             # [H, P, N] f32
    H, P = xdt.shape[1], xdt.shape[2]
    N = Bm.shape[1]

    ii = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    tri = (ii >= jj).astype(jnp.float32)
    # inclusive prefix sums via triangular matmul: cs[i] = Σ_{t≤i} dA[t]
    cs = jnp.dot(tri, dA, preferred_element_type=jnp.float32)        # [c, H]
    cnt = jnp.dot(tri, rst[:, None],
                  preferred_element_type=jnp.float32)[:, 0]          # [c]

    # 1) intra-chunk quadratic term: (j → i) decays exp(cs_i − cs_j) and is
    # masked out when a reset falls in (j, i] (different inclusive counts)
    same = cnt[:, None] == cnt[None, :]
    run_ok = (ii >= jj) & same
    dec_ij = jnp.exp(cs[:, None, :] - cs[None, :, :])                # [c,c,H]
    L = jnp.where(run_ok[..., None], dec_ij, 0.0)
    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)   # [c, c]
    M = (scores[..., None] * L).transpose(2, 0, 1)                   # [H,c,c]
    xh = xdt.transpose(1, 0, 2)                                      # [H,c,P]
    y_diag = jax.lax.dot_general(
        M, xh, (((2,), (1,)), ((0,), (0,))))                         # [H,c,P]

    # 2) incoming-state term: token i sees the carried state iff no reset ≤ i
    gate0 = jnp.where(cnt == 0.0, 1.0, 0.0)                          # [c]
    csx = jnp.exp(cs) * gate0[:, None]                               # [c, H]
    c_st = jax.lax.dot_general(
        Cm, state_in, (((1,), (2,)), ((), ())))                      # [c,H,P]
    y_ref[...] = y_diag.transpose(1, 0, 2) + c_st * csx[..., None]

    # 3) per-request state capture (state AFTER flat row cap_rows[r])
    cap = cap_ref[...]                                               # [R] i32
    loc = cap - i * c
    in_ch = (loc >= 0) & (loc < c)
    loc_c = jnp.clip(loc, 0, c - 1)
    rr = jax.lax.broadcasted_iota(jnp.int32, (r_cap, c), 1)
    onehot = ((rr == loc_c[:, None]) & in_ch[:, None]).astype(jnp.float32)
    cs_at = jnp.dot(onehot, cs, preferred_element_type=jnp.float32)  # [R, H]
    cnt_at = jnp.dot(onehot, cnt[:, None],
                     preferred_element_type=jnp.float32)[:, 0]       # [R]
    wmask = (rr <= loc_c[:, None]) & in_ch[:, None] \
        & (cnt[None, :] == cnt_at[:, None])
    w = jnp.where(wmask[..., None],
                  jnp.exp(cs_at[:, None, :] - cs[None, :, :]), 0.0)  # [R,c,H]
    G = xdt[:, :, :, None] * Bm[:, None, None, :]                    # [c,H,P,N]
    Gh = G.transpose(1, 0, 2, 3).reshape(H, c, P * N)
    wh = w.transpose(2, 0, 1)                                        # [H,R,c]
    contrib = jax.lax.dot_general(
        wh, Gh, (((2,), (1,)), ((0,), (0,))))                        # [H,R,PN]
    contrib = contrib.reshape(H, r_cap, P, N).transpose(1, 0, 2, 3)
    basef = jnp.where(in_ch & (cnt_at == 0.0), 1.0, 0.0)             # [R]
    base = jnp.exp(cs_at) * basef[:, None]                           # [R, H]
    cap_out_ref[...] += contrib + base[..., None, None] * state_in[None]

    # 4) chunk-end state for the inter-chunk recurrence
    endg = jnp.where(cnt[-1] == cnt, 1.0, 0.0)                       # [c]
    dec = jnp.exp(cs[-1][None, :] - cs) * endg[:, None]              # [c, H]
    dxh = (dec[..., None] * xdt).transpose(1, 2, 0)                  # [H,P,c]
    delta = jax.lax.dot_general(
        dxh, Bm, (((2,), (0,)), ((), ())))                           # [H,P,N]
    keep = jnp.where(cnt[-1] == 0.0, 1.0, 0.0)
    state_ref[...] = state_in * (jnp.exp(cs[-1]) * keep)[:, None, None] \
        + delta


@functools.partial(JC.jit, static_argnames=("chunk", "interpret"))
def ssm_segment_scan_call(
    xdt: jax.Array,       # [T, H, P] f32  pre-multiplied x · dt
    dA: jax.Array,        # [T, H]    f32  dt · A (negative)
    Bm: jax.Array,        # [T, N]    f32
    Cm: jax.Array,        # [T, N]    f32
    reset: jax.Array,     # [T]       f32  1.0 at segment-start tokens
    cap_rows: jax.Array,  # [R]       i32  flat row of each capture (−1: zero)
    *,
    chunk: int = 64,
    interpret: bool = True,
):
    """Returns (y [T, H, P] f32, captured states [R, H, P, N] f32,
    final state [H, P, N] f32)."""
    T, H, P = xdt.shape
    N = Bm.shape[1]
    R = cap_rows.shape[0]
    assert T % chunk == 0, (T, chunk)
    n_chunks = T // chunk
    kern = functools.partial(_kernel, c=chunk, r_cap=R)
    y, cap, state = pl.pallas_call(
        kern,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((chunk, H, P), lambda i: (i, 0, 0)),
            pl.BlockSpec((chunk, H), lambda i: (i, 0)),
            pl.BlockSpec((chunk, N), lambda i: (i, 0)),
            pl.BlockSpec((chunk, N), lambda i: (i, 0)),
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((R,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((chunk, H, P), lambda i: (i, 0, 0)),
            pl.BlockSpec((R, H, P, N), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((H, P, N), lambda i: (0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, H, P), jnp.float32),
            jax.ShapeDtypeStruct((R, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, dA, Bm, Cm, reset, cap_rows)
    return y, cap, state
