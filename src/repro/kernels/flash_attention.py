"""Packed-KV flash attention — the Reuse-phase hot loop (paper C2/C3).

Computes attention of active-block queries over the *head-centric dense
packed* KV cache (plus the live block KV appended by the caller). Because the
paper's C3 packs retained tokens contiguously at Refresh time, this kernel
reads K/V tiles with plain sequential DMA — no gather, no indirection — which
is exactly the property the paper trades per-head top-k flexibility for.

Contract (matches ``transformer._attend_packed``):
  q    [B, K, R, dh]   R = Sb·G query rows per KV head (GQA groups flattened)
  k,v  [B, K, T, dh]   head-major packed KV (+ live block appended)
  mask [B, K, Sb, T]   validity/window/causality (broadcast over the G axis)
  out  [B, K, R, dh] f32 (unnormalized; ops.py divides by the softmax sum)

Grid ``(B, K, T//T_tile)``: online-softmax accumulation across KV tiles into
revisited output blocks, flash-attention style. m/s carried as [B, K, R]
outputs (portable across interpret/TPU; no scratch dependence).

VMEM per step at (Sb=32, G=8 → R=256, dh=256, T_tile=512):
q 128 KB + k/v 2·256 KB + acc 256 KB + mask 16 KB ≈ 1 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from jax.experimental import pallas as pl

from repro import jax_compat as JC


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, s_ref,
            *, scale: float, softcap: float, g: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        s_ref[...] = jnp.zeros_like(s_ref)

    q = q_ref[0, 0]          # [R, dh]
    k = k_ref[0, 0]          # [Tt, dh]
    v = v_ref[0, 0]          # [Tt, dh]
    mk = mask_ref[0, 0]      # [Sb, Tt] bool

    z = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [R, Tt]
    if softcap:
        z = softcap * jnp.tanh(z / softcap)
    R, Tt = z.shape
    zm = z.reshape(R // g, g, Tt)
    zm = jnp.where(mk[:, None, :], zm, -1e30)
    z = zm.reshape(R, Tt)

    m_old = m_ref[0, 0]                       # [R]
    local_m = jnp.max(z, axis=1)
    m_new = jnp.maximum(m_old, local_m)
    alpha = jnp.exp(m_old - m_new)            # rescale previous accumulators
    p = jnp.exp(z - m_new[:, None])           # [R, Tt]
    s_ref[0, 0] = s_ref[0, 0] * alpha + jnp.sum(p, axis=1)
    o_ref[0, 0] = (o_ref[0, 0] * alpha[:, None]
                   + jnp.dot(p.astype(v.dtype), v,
                             preferred_element_type=jnp.float32))
    m_ref[0, 0] = m_new


@functools.partial(JC.jit, static_argnames=("softcap", "t_tile", "interpret"))
def packed_flash_attention_call(
    q: jax.Array,        # [B, K, R, dh]
    k: jax.Array,        # [B, K, T, dh]
    v: jax.Array,        # [B, K, T, dh]
    mask: jax.Array,     # [B, K, Sb, T] bool
    *,
    softcap: float = 0.0,
    t_tile: int = 512,
    interpret: bool = True,
):
    B, K, R, dh = q.shape
    T = k.shape[2]
    Sb = mask.shape[2]
    g = R // Sb
    t_tile = min(t_tile, T)
    assert T % t_tile == 0, (T, t_tile)
    n_t = T // t_tile
    kern = functools.partial(_kernel, scale=dh ** -0.5, softcap=softcap, g=g)
    out, m, s = pl.pallas_call(
        kern,
        grid=(B, K, n_t),
        in_specs=[
            pl.BlockSpec((1, 1, R, dh), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, t_tile, dh), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, t_tile, dh), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, Sb, t_tile), lambda b, h, j: (b, h, 0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, R, dh), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, R), lambda b, h, j: (b, h, 0)),
            pl.BlockSpec((1, 1, R), lambda b, h, j: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, K, R, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, K, R), jnp.float32),
            jax.ShapeDtypeStruct((B, K, R), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, mask)
    return out, m, s
