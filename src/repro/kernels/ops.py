"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (the validation mode required here) and
False on real TPU backends. Each wrapper adapts the model-layer calling
convention ([B, S, H, dh] tensors) to the kernels' head-major packed layout.

Mesh dispatch: every serving hot path consults
``jax_compat.get_active_mesh()`` at trace time (the engine activates its mesh
around each stage dispatch) and, on a model axis > 1, shard_maps the kernel
per shard — varlen attention over its local query/KV heads, the segment-reset
SSD scan over its local state heads, the fused logit argmax over its local
vocab shard with a cross-shard (max, index, logsumexp) reduce. Indivisible
head/vocab counts raise at trace time instead of silently falling back; the
engine pre-validates the same law (``launch.sharding.kernel_partition_plan``)
so serving configs fail at construction, not mid-trace.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.jax_compat import P
from repro.kernels import ref
from repro.kernels.flash_attention import packed_flash_attention_call
from repro.kernels.logit_argmax import fused_logit_argmax_call
from repro.kernels.select_pack import head_score_call, head_score_varlen_call


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _mesh_model():
    """(mesh, model-axis size) of the enclosing ``use_mesh`` scope.

    (None, 1) when no mesh — or no ``model`` axis — is active at trace time,
    which keeps the no-mesh path byte-for-byte the single-device dispatch.
    A 1-sized model axis also dispatches locally (bit-identical 1×1 law)."""
    from repro.jax_compat import get_active_mesh
    mesh = get_active_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None, 1
    m = mesh.shape["model"]
    return (mesh, m) if m > 1 else (None, 1)


def _require_divisible(kernel: str, **dims) -> None:
    """Fail-loud divisibility law for per-shard kernel dispatch (mirrors
    ``launch.sharding.kernel_partition_plan``): never silently fall back."""
    m = dims.pop("m")
    bad = [f"{k}={v}" for k, v in dims.items() if v % m]
    if bad:
        raise ValueError(
            f"{kernel} cannot partition over the {m}-way model axis: "
            f"{', '.join(bad)} must divide it exactly")


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def fused_logit_argmax(h, w, *, softcap: float = 0.0, vocab_tile: int = 512,
                       t_tile: int = 256, w_layout: str = "dv", valid=None):
    """h: [T, D]; w: [D, V] ("dv") or [V, D] ("vd", tied-embedding table).
    Returns (ids [T] i32, conf [T] f32). Paper C1, fused.

    ``valid`` ([T] bool, optional) marks real rows of a token-bucketed packed
    stream: the kernel skips the V loop of all-padding T-tiles entirely and
    invalid rows decode to (0, 0.0)."""
    T = h.shape[0]
    V = w.shape[1] if w_layout == "dv" else w.shape[0]
    t_tile = min(t_tile, max(8, T))
    hp, _ = _pad_to(h, t_tile, 0)
    vld = jnp.ones((T,), bool) if valid is None else valid
    vp, _ = _pad_to(vld, t_tile, 0)
    mesh, msize = _mesh_model()
    if mesh is not None:
        ids, m, s = _sharded_logit_argmax(
            hp, w, vp, mesh, msize, V, softcap=softcap, t_tile=t_tile,
            vocab_tile=vocab_tile, w_layout=w_layout)
    else:
        # vocab tile must divide V (all assigned vocabs are 8-divisible);
        # zero padding would fabricate logit-0 columns, so fall back to ref.
        vt = vocab_tile
        while V % vt:
            vt //= 2
            if vt < 8:
                wd = w if w_layout == "dv" else w.T
                ids, conf = ref.fused_logit_argmax(h, wd, softcap=softcap)
                if valid is not None:
                    ids = jnp.where(valid, ids, 0)
                    conf = jnp.where(valid, conf, 0.0)
                return ids, conf
        ids, m, s = fused_logit_argmax_call(
            hp, w, vp, softcap=softcap, t_tile=t_tile, v_tile=vt,
            interpret=_interpret(), w_layout=w_layout)
    conf = 1.0 / jnp.maximum(s, 1e-30)
    ids, conf = ids[:T], conf[:T]
    if valid is not None:
        ids = jnp.where(valid, ids, 0)
        conf = jnp.where(valid, conf, 0.0)
    return ids, conf


def _sharded_logit_argmax(hp, w, vp, mesh, msize, V, *, softcap, t_tile,
                          vocab_tile, w_layout):
    """Vocab-sharded fused argmax: each model shard runs the Pallas kernel
    over its local [T, V/m] vocab slice, then a cheap cross-shard reduce
    merges (max, argmax-index, logsumexp) — pmax for the running max, pmin
    over offset-shifted indices among max-achieving shards (preserving the
    single-device lowest-index tie-break, since a lower shard id means a
    lower global vocab offset), and a psum of the rescaled softmax sums."""
    _require_divisible("fused logit argmax", m=msize, vocab_size=V)
    v_loc = V // msize
    vt = min(vocab_tile, v_loc)
    while v_loc % vt:
        vt //= 2
        if vt < 8:
            raise ValueError(
                "fused logit argmax: no >=8-column vocab tile divides the "
                f"per-shard vocab {v_loc} (vocab {V} over {msize} shards)")
    from repro.jax_compat import shard_map as _shard_map
    w_spec = P(None, "model") if w_layout == "dv" else P("model", None)
    interp = _interpret()

    def local(hp_l, w_l, vp_l):
        ids, m, s = fused_logit_argmax_call(
            hp_l, w_l, vp_l, softcap=softcap, t_tile=t_tile, v_tile=vt,
            interpret=interp, w_layout=w_layout)
        off = jax.lax.axis_index("model").astype(jnp.int32) * v_loc
        gids = ids.astype(jnp.int32) + off
        m_max = jax.lax.pmax(m, "model")
        big = jnp.int32(jnp.iinfo(jnp.int32).max)
        gid = jax.lax.pmin(jnp.where(m == m_max, gids, big), "model")
        s_g = jax.lax.psum(s * jnp.exp(m - m_max), "model")
        return gid, m_max, s_g

    return _shard_map(
        local, mesh=mesh,
        in_specs=(P(None, None), w_spec, P(None)),
        out_specs=(P(None), P(None), P(None)),
        check_vma=False,
    )(hp, w, vp)


def packed_flash_attention_stats(qr, k_all, v_all, ok, *, softcap: float = 0.0,
                                 t_tile: int = 512):
    """Raw flash statistics for exact split-attention merging.

    qr: [B, K, R, dh] (rows = Sb·G); returns (o_unnorm f32 [B,K,R,dh],
    m [B,K,R], s [B,K,R]).
    """
    T = k_all.shape[2]
    tt = min(t_tile, T)
    while T % tt:
        tt //= 2
    return packed_flash_attention_call(
        qr, k_all, v_all, ok, softcap=softcap, t_tile=tt,
        interpret=_interpret())


def packed_flash_attention(q, k_all, v_all, ok, *, softcap: float = 0.0,
                           t_tile: int = 512):
    """Model-layer contract (see ``transformer._attend_packed``):

    q: [B, Sb, H, dh]; k_all/v_all: [B, K, T, dh]; ok: [B, K, Sb, T] bool.
    Returns [B, Sb, H, dh].
    """
    B, Sb, H, dh = q.shape
    K, T = k_all.shape[1], k_all.shape[2]
    G = H // K
    qr = (q.reshape(B, Sb, K, G, dh).transpose(0, 2, 1, 3, 4)
          .reshape(B, K, Sb * G, dh))
    tt = min(t_tile, T)
    while T % tt:
        tt //= 2
    out, m, s = packed_flash_attention_call(
        qr, k_all, v_all, ok, softcap=softcap, t_tile=tt,
        interpret=_interpret())
    out = out / jnp.maximum(s, 1e-30)[..., None]
    out = (out.reshape(B, K, Sb, G, dh).transpose(0, 2, 1, 3, 4)
           .reshape(B, Sb, H, dh))
    return out.astype(q.dtype)


def flash_refresh_attention(q, k, v, *, q_pos, kv_pos, kv_valid, mask_mode,
                            window, is_local, softcap, q_tile: int = 256,
                            kv_tile: int = 512):
    """Refresh-phase flash attention (model-layer contract).

    q: [B, S, H, dh]; k/v: [B, S, K, dh]; returns [B, S, H, dh].
    Under an active mesh the call is shard_mapped: batch over the data axes
    and heads over 'model' when H divides it (each shard slices its KV-head
    range locally; KV stays replicated over 'model' — GQA KV heads below the
    TP degree are replicated anyway).
    """
    import numpy as np
    from repro.kernels.flash_refresh import flash_refresh_call

    B, S, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    causal = mask_mode == "causal"
    loc = jnp.asarray(is_local, bool).reshape(1)

    qh = q.transpose(0, 2, 1, 3)        # [B, H, S, dh]
    kh = k.transpose(0, 2, 1, 3)        # [B, K, S, dh]
    vh = v.transpose(0, 2, 1, 3)

    def local_call(q_l, k_l, v_l, qp, kp, kv, lc, *, h_shards: int = 1):
        H_loc, Sq = q_l.shape[1], q_l.shape[2]
        if h_shards > 1:
            idx = jax.lax.axis_index("model")
            K_eff = max(1, H_loc // G)
            kv_start = (idx * H_loc) // G
            k_l = jax.lax.dynamic_slice_in_dim(k_l, kv_start, K_eff, axis=1)
            v_l = jax.lax.dynamic_slice_in_dim(v_l, kv_start, K_eff, axis=1)
        else:
            K_eff = K
        G_eff = H_loc // K_eff
        Bl = q_l.shape[0]
        qr = (q_l.reshape(Bl, K_eff, G_eff, Sq, dh).transpose(0, 1, 3, 2, 4)
              .reshape(Bl, K_eff, Sq * G_eff, dh))
        out = flash_refresh_call(
            qr, k_l, v_l, qp, kp, kv, lc, softcap=softcap, causal=causal,
            window=window, q_tile=min(q_tile, Sq),
            kv_tile=min(kv_tile, k_l.shape[2]),
            interpret=_interpret())
        out = (out.reshape(Bl, K_eff, Sq, G_eff, dh).transpose(0, 1, 3, 2, 4)
               .reshape(Bl, H_loc, Sq, dh))
        return out.astype(q_l.dtype)

    from repro.jax_compat import get_active_mesh, shard_map as _shard_map
    mesh = get_active_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        out = local_call(qh, kh, vh, q_pos, kv_pos, kv_valid, loc)
    else:
        m = mesh.shape["model"]
        dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        import functools as ft
        if H % m == 0:
            # TP over heads; each shard slices its KV-head range locally
            fn = ft.partial(local_call, h_shards=m)
            q_spec = out_spec = P(dp, "model", None, None)
            qp_spec = P(dp, None)
        elif S % m == 0:
            # heads don't divide the TP axis (e.g. H=40 on 16): shard the
            # QUERY sequence axis instead — every query row's output is
            # complete against the replicated KV, so no psum is needed.
            # §Perf iteration C2: engages idle TP compute for refresh.
            fn = local_call
            q_spec = out_spec = P(dp, None, "model", None)
            qp_spec = P(dp, "model")
        else:
            fn = local_call
            q_spec = out_spec = P(dp, None, None, None)
            qp_spec = P(dp, None)
        out = _shard_map(
            fn, mesh=mesh,
            in_specs=(q_spec, P(dp, None, None, None),
                      P(dp, None, None, None), qp_spec, P(dp, None),
                      P(dp, None), P(None)),
            out_specs=out_spec,
            check_vma=False,
        )(qh, kh, vh, q_pos, kv_pos, kv_valid, loc)
    return out.transpose(0, 2, 1, 3)    # back to [B, S, H, dh]


def flash_varlen_attention(q, k, v, *, seg_ids, positions, kv_valid,
                           window: int = 0, is_local=False,
                           softcap: float = 0.0, causal: bool = False,
                           q_tile: int = 256, kv_tile: int = 512):
    """Ragged flash attention over a token-packed stream (model contract).

    q: [T, H, dh]; k/v: [T, K, dh]; seg_ids/positions: [T] int32 (segment id
    ascending, position within the owning request); kv_valid: [T] bool.
    Returns [T, H, dh]. One flat dispatch replaces the padded [B, S] batch;
    cross-request attention is masked in-kernel via segment ids and
    non-intersecting tiles are skipped (FLOPs ~ Σ Sᵢ², not T²).
    """
    from repro.kernels.flash_varlen import flash_varlen_call

    T, H, dh = q.shape
    K = k.shape[1]
    qt = min(q_tile, T)
    while T % qt:
        qt //= 2
    kt = min(kv_tile, T)
    while T % kt:
        kt //= 2
    loc = jnp.asarray(is_local, bool).reshape(1)
    interp = _interpret()

    def local_call(q_l, k_l, v_l, pos, seg, kvv, lc):
        # per-shard geometry: contiguous H/m query-head blocks align with
        # K/m KV-head blocks (both divide), so GQA grouping is shard-local
        H_l, K_l = q_l.shape[1], k_l.shape[1]
        G_l = H_l // K_l
        qr = (q_l.reshape(T, K_l, G_l, dh).transpose(1, 0, 2, 3)
              .reshape(K_l, T * G_l, dh))
        out = flash_varlen_call(
            qr, k_l.transpose(1, 0, 2), v_l.transpose(1, 0, 2),
            pos.astype(jnp.int32), seg.astype(jnp.int32), kvv, lc,
            softcap=softcap, causal=causal, window=window,
            q_tile=qt, kv_tile=kt, interpret=interp)
        out = (out.reshape(K_l, T, G_l, dh).transpose(1, 0, 2, 3)
               .reshape(T, H_l, dh))
        return out.astype(q_l.dtype)

    mesh, msize = _mesh_model()
    if mesh is None:
        return local_call(q, k, v, positions, seg_ids, kv_valid, loc)
    _require_divisible("varlen flash attention", m=msize, n_heads=H,
                       n_kv_heads=K)
    from repro.jax_compat import shard_map as _shard_map
    h_spec = P(None, "model", None)
    return _shard_map(
        local_call, mesh=mesh,
        in_specs=(h_spec, h_spec, h_spec, P(None), P(None), P(None), P(None)),
        out_specs=h_spec,
        check_vma=False,
    )(q, k, v, positions, seg_ids, kv_valid, loc)


def flash_varlen_cross_attention(q, k, v, *, q_seg, q_pos, kv_seg, kv_pos,
                                 kv_valid, window: int = 0, is_local=False,
                                 softcap: float = 0.0, causal: bool = False,
                                 q_tile: int = 128, kv_tile: int = 512):
    """Packed-Reuse cross attention (model contract).

    q: [Tq, H, dh] flat packed block queries; k/v: [K, Tkv, dh] head-major
    flat KV stream ([retain ; live block] per request, requests contiguous);
    q_seg/q_pos: [Tq] int32; kv_seg: [Tkv] int32; kv_pos/kv_valid: [K, Tkv]
    (head-centric selection retains different tokens per KV head). Returns
    [Tq, H, dh]. One flat dispatch replaces the pow2-bucketed [B, Sb] Reuse
    batch; non-owned KV tiles are skipped in-kernel.
    """
    from repro.kernels.flash_varlen import flash_varlen_cross_call

    Tq, H, dh = q.shape
    K, Tkv = k.shape[0], k.shape[1]
    qt = min(q_tile, Tq)
    while Tq % qt:
        qt //= 2
    kt = min(kv_tile, Tkv)
    while Tkv % kt:
        kt //= 2
    loc = jnp.asarray(is_local, bool).reshape(1)
    interp = _interpret()

    def local_call(q_l, k_l, v_l, qp, kvp, qs, kvs, kvv, lc):
        H_l, K_l = q_l.shape[1], k_l.shape[0]
        G_l = H_l // K_l
        qr = (q_l.reshape(Tq, K_l, G_l, dh).transpose(1, 0, 2, 3)
              .reshape(K_l, Tq * G_l, dh))
        out = flash_varlen_cross_call(
            qr, k_l, v_l, qp.astype(jnp.int32), kvp.astype(jnp.int32),
            qs.astype(jnp.int32), kvs.astype(jnp.int32), kvv, lc,
            softcap=softcap, causal=causal, window=window,
            q_tile=qt, kv_tile=kt, interpret=interp)
        out = (out.reshape(K_l, Tq, G_l, dh).transpose(1, 0, 2, 3)
               .reshape(Tq, H_l, dh))
        return out.astype(q_l.dtype)

    mesh, msize = _mesh_model()
    if mesh is None:
        return local_call(q, k, v, q_pos, kv_pos, q_seg, kv_seg,
                          kv_valid, loc)
    # the head-major KV stream is already head-sharded ([K, Tkv, dh] built
    # from the Rules.cache head-sharded pool) — each shard consumes its
    # local KV heads directly, no all-gather of KV
    _require_divisible("varlen cross attention", m=msize, n_heads=H,
                       n_kv_heads=K)
    from repro.jax_compat import shard_map as _shard_map
    return _shard_map(
        local_call, mesh=mesh,
        in_specs=(P(None, "model", None), P("model", None, None),
                  P("model", None, None), P(None), P("model", None),
                  P(None), P(None), P("model", None), P(None)),
        out_specs=P(None, "model", None),
        check_vma=False,
    )(q, k, v, q_pos, kv_pos, q_seg, kv_seg, kv_valid, loc)


def ssm_segment_scan(xh, dt, A, Bm, Cm, reset, cap_rows, *, chunk: int = 64):
    """Segment-reset SSD scan over a packed stream (model contract).

    xh: [T, H, P]; dt: [T, H] f32 (post-softplus); A: [H] (negative);
    Bm/Cm: [T, N]; reset: [T] bool (True on each request's first token);
    cap_rows: [R] int32 flat row AFTER which request r's state is captured
    (−1 → zero state). Returns (y [T, H, P] f32, states [R, H, P, N] f32).
    One flat dispatch replaces the padded ``[B, max_seq_len]`` scan — the
    recurrent state resets at segment boundaries in-kernel and the captured
    states are accumulated without materializing per-token states.
    """
    from repro.kernels.ssm_scan import ssm_segment_scan_call

    T, H = xh.shape[0], xh.shape[1]
    f32 = jnp.float32
    ct = min(chunk, T)
    while T % ct:
        ct //= 2
    dtf = dt.astype(f32)
    xdt = xh.astype(f32) * dtf[..., None]
    dA = dtf * A.astype(f32)[None, :]
    interp = _interpret()

    def local_call(xdt_l, dA_l, Bm_l, Cm_l, reset_l, cap_l):
        y, cap, _ = ssm_segment_scan_call(
            xdt_l, dA_l, Bm_l, Cm_l, reset_l, cap_l, chunk=ct,
            interpret=interp)
        return y, cap

    mesh, msize = _mesh_model()
    if mesh is None:
        return local_call(xdt, dA, Bm.astype(f32), Cm.astype(f32),
                          reset.astype(f32), cap_rows.astype(jnp.int32))
    # shard the state-head axis; each shard scans and captures its local
    # [R, H/m, P, N] states — matching the Rules.ssm_cache head-sharded pool
    _require_divisible("varlen SSD scan", m=msize, ssm_heads=H)
    from repro.jax_compat import shard_map as _shard_map
    return _shard_map(
        local_call, mesh=mesh,
        in_specs=(P(None, "model", None), P(None, "model"), P(None, None),
                  P(None, None), P(None), P(None)),
        out_specs=(P(None, "model", None), P(None, "model", None, None)),
        check_vma=False,
    )(xdt, dA, Bm.astype(f32), Cm.astype(f32), reset.astype(f32),
      cap_rows.astype(jnp.int32))


def head_score(q_block, k_full, *, s_tile: int = 512):
    """q_block: [B, Sb, H, dh]; k_full: [B, S, K, dh] -> [B, K, S] f32 raw
    (pre-maxpool) importance scores — kernel side of paper C3 eq.(6)."""
    B, Sb, H, dh = q_block.shape
    K, S = k_full.shape[2], k_full.shape[1]
    G = H // K
    qr = (q_block.reshape(B, Sb, K, G, dh).transpose(0, 2, 1, 3, 4)
          .reshape(B, K, Sb * G, dh))
    kr = k_full.transpose(0, 2, 1, 3)
    st = min(s_tile, S)
    while S % st:
        st //= 2
    return head_score_call(qr, kr, s_tile=st, interpret=_interpret())


def head_score_varlen(q_block, k_flat, seg_ids, *, s_tile: int = 512):
    """q_block: [R, Sb, H, dh]; k_flat: [T, K, dh] flat packed stream;
    seg_ids: [T] int32 -> [R, K, T] f32 raw scores (-inf off-segment).
    Tile-skipping varlen side of paper C3 eq.(6) — no padded K gather."""
    R, Sb, H, dh = q_block.shape
    T, K = k_flat.shape[0], k_flat.shape[1]
    st = min(s_tile, T)
    while T % st:
        st //= 2
    interp = _interpret()

    def local_call(q_l, k_l, seg):
        H_l, K_l = q_l.shape[2], k_l.shape[1]
        G_l = H_l // K_l
        qr = (q_l.reshape(R, Sb, K_l, G_l, dh).transpose(0, 2, 1, 3, 4)
              .reshape(R, K_l, Sb * G_l, dh))
        return head_score_varlen_call(qr, k_l.transpose(1, 0, 2),
                                      seg.astype(jnp.int32), s_tile=st,
                                      interpret=interp)

    mesh, msize = _mesh_model()
    if mesh is None:
        return local_call(q_block, k_flat, seg_ids)
    _require_divisible("varlen head-score", m=msize, n_heads=H,
                       n_kv_heads=K)
    from repro.jax_compat import shard_map as _shard_map
    return _shard_map(
        local_call, mesh=mesh,
        in_specs=(P(None, None, "model", None), P(None, "model", None),
                  P(None)),
        out_specs=P(None, "model", None),
        check_vma=False,
    )(q_block, k_flat, seg_ids)


def dequantize_gathered(gathered, kv_quant: str, dtypes):
    """KV-load dequantization point for the Reuse stages (docs/memory.md).

    Under ``ServeConfig.kv_quant="int8"`` the slot pool's gather returns
    the QUANTIZED view ``{"data": int8-leaf tree, "scale": per-leaf
    [L, B] f32}`` so the pool — and the HBM traffic across the gather —
    stays int8; this helper, called at the top of every Reuse stage jit
    (packed varlen kernels and the padded jnp oracle alike), scales the KV
    leaves back to ``dtypes`` inside the SAME XLA program as the attention
    kernels, so the dequantized tensors are transient activations fused
    into the kernel's KV load, never pool state.

    The unquantized path passes the gathered cache through untouched —
    billed as itself (the bit-exact oracle); there is no silent third mode
    (`KVPool` validates ``kv_quant`` at construction).
    """
    if kv_quant == "none":
        return gathered
    from repro.kernels.kv_quant import dequantize_slot_leaves
    return dequantize_slot_leaves(gathered["data"], gathered["scale"],
                                  dtypes)
