"""int8 KV slot storage: per-(layer, slot) abs-max quantization.

The slot pool's float KV leaves (``PackedKV.k`` / ``PackedKV.v`` — dense,
hybrid's attention group, never the SSM f32 recurrent state, whose dynamic
range a per-slot scale cannot honestly cover) are stored as int8 with one
float32 scale per (layer, slot). Quantization happens inside the pool's
scatter jit at Refresh write time; dequantization happens at the KV load
of the Reuse stage (``kernels.ops.dequantize_gathered``) so the pool —
and the gather crossing back out of it — stays int8 in HBM and the
dequantized tensors are transient activations fused into the same XLA
program as the attention kernels.

Error contract (tested per dtype in ``tests/test_kv_share.py``): symmetric
round-to-nearest over the per-(layer, slot) abs-max means

    |x - dequant(quant(x))|  <=  scale / 2  =  absmax / 254

for float32 leaves, plus one target-dtype rounding step (~``absmax/256``)
for bfloat16. The documented serving tolerance (docs/memory.md) follows
from this bound; ``kv_quant="none"`` keeps the pool bit-exact.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

_QMAX = 127.0


def quant_mask(tree):
    """Same-structure tree of bools: True at the leaves int8 slot storage
    applies to (PackedKV ``k``/``v``), False elsewhere (positions, validity,
    SSM state/conv). The single predicate both the pool's runtime jits and
    ``budgeting.kv_slot_bytes``'s analytic billing read — one law, no
    drift."""
    from repro.models.sparse_select import PackedKV

    def expand(node):
        if isinstance(node, PackedKV):
            return PackedKV(k=True, v=True, pos=False, valid=False)
        return False

    return jax.tree.map(expand, tree,
                        is_leaf=lambda x: isinstance(x, PackedKV))


def quant_leaf_flags(tree) -> list:
    """Flattened :func:`quant_mask`, aligned with ``jax.tree.leaves``
    (the mask's leaves are plain Python bools)."""
    return jax.tree.leaves(quant_mask(tree))


def _bcast(scale: jax.Array, ndim: int) -> jax.Array:
    """[L, B] scale broadcast over a leaf's trailing content dims."""
    return scale.reshape(scale.shape + (1,) * (ndim - 2))


def quantize_slot_leaves(cache) -> Tuple[object, Dict[str, jax.Array]]:
    """Quantize a cache pytree's KV leaves (``[L, B, ...]``, slot axis 1).

    Returns the same-structure tree with int8 KV leaves, plus a dict of
    per-leaf ``[L, B]`` float32 scales keyed by flattened-leaf index (a
    plain dict pytree — no placeholder leaves at unquantized positions).
    """
    leaves, treedef = jax.tree.flatten(cache)
    flags = quant_leaf_flags(cache)
    out, scales = [], {}
    for i, (x, q) in enumerate(zip(leaves, flags)):
        if not q:
            out.append(x)
            continue
        xf = x.astype(jnp.float32)
        amax = jnp.max(jnp.abs(xf), axis=tuple(range(2, x.ndim)))
        scale = jnp.maximum(amax, jnp.float32(1e-12)) / _QMAX
        qx = jnp.clip(jnp.round(xf / _bcast(scale, x.ndim)),
                      -_QMAX, _QMAX).astype(jnp.int8)
        out.append(qx)
        scales[str(i)] = scale
    return jax.tree.unflatten(treedef, out), scales


def dequantize_slot_leaves(qcache, scales: Dict[str, jax.Array],
                           dtypes: Dict[str, object]):
    """Inverse of :func:`quantize_slot_leaves` for a (sliced) pool view:
    int8 KV leaves scaled back to their original dtype (``dtypes`` carries
    the pre-quantization leaf dtypes by the same flattened index)."""
    leaves, treedef = jax.tree.flatten(qcache)
    out = []
    for i, x in enumerate(leaves):
        s = scales.get(str(i))
        if s is None:
            out.append(x)
            continue
        out.append((x.astype(jnp.float32) * _bcast(s, x.ndim))
                   .astype(dtypes[str(i)]))
    return jax.tree.unflatten(treedef, out)


def roundtrip_bound(absmax: float, dtype) -> float:
    """Documented worst-case |x - dq(q(x))| for one value with per-slot
    abs-max ``absmax``: half a quantization step, plus one ulp-scale term
    when the storage round-trips through a reduced-precision target."""
    step = absmax / _QMAX
    extra = absmax / 256.0 if jnp.dtype(dtype) == jnp.bfloat16 else 0.0
    return step / 2.0 + extra
