"""Per-head importance scoring kernel — the Refresh-phase side of paper C3.

Computes the raw per-KV-head alignment scores
``raw[b, k, s] = max_{q in block, g in group} (Q_{b,q,k,g} · K_{b,s,k})``
— the inner product of paper eq.(6) before local max-pooling. The pooling
(kernel size w, a [B,K,S] stencil) and the top-k + single gather run as
cheap XLA ops in ``ops.py``; the O(S·Sb·G·dh) matmul is the hot part and
lives here.

Grid ``(B, K, S//S_tile)``; each step is a ``[S_tile, dh] × [dh, R]`` MXU
matmul followed by a row max — no cross-step state, fully parallel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from jax.experimental import pallas as pl

from repro import jax_compat as JC


def _kernel(q_ref, k_ref, s_ref):
    q = q_ref[0, 0]        # [R, dh] block queries (Sb·G rows)
    k = k_ref[0, 0]        # [S_tile, dh]
    z = jnp.dot(k, q.T, preferred_element_type=jnp.float32)   # [S_tile, R]
    s_ref[0, 0] = jnp.max(z, axis=1)


def _varlen_kernel(q_ref, k_ref, seg_ref, s_ref):
    """Varlen scoring over the flat token-packed stream (whole-iteration
    packing): request r's block queries score ONLY the key tiles whose
    segment-id range contains r — the select/pack analogue of the attention
    kernel's tile-skip. Non-owned positions score ``-inf`` (the same sentinel
    the padded path uses for invalid rows), so the downstream max-pool can
    never leak a neighbour request's relevance across a boundary."""
    r = pl.program_id(0)
    ks = seg_ref[...]                 # [S_tile]
    overlap = (jnp.min(ks) <= r) & (r <= jnp.max(ks))

    @pl.when(overlap)
    def _compute():
        q = q_ref[0, 0]               # [R, dh]
        k = k_ref[0]                  # [S_tile, dh]
        z = jnp.dot(k, q.T, preferred_element_type=jnp.float32)
        s_ref[0, 0] = jnp.where(ks == r, jnp.max(z, axis=1), -jnp.inf)

    @pl.when(~overlap)
    def _skip():
        s_ref[0, 0] = jnp.full_like(s_ref[0, 0], -jnp.inf)


@functools.partial(JC.jit, static_argnames=("s_tile", "interpret"))
def head_score_call(
    q: jax.Array,     # [B, K, R, dh]  block queries, groups flattened
    k: jax.Array,     # [B, K, S, dh]  full-sequence keys, head-major
    *,
    s_tile: int = 512,
    interpret: bool = True,
):
    B, K, R, dh = q.shape
    S = k.shape[2]
    s_tile = min(s_tile, S)
    assert S % s_tile == 0, (S, s_tile)
    out = pl.pallas_call(
        _kernel,
        grid=(B, K, S // s_tile),
        in_specs=[
            pl.BlockSpec((1, 1, R, dh), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, s_tile, dh), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, s_tile), lambda b, h, j: (b, h, j)),
        out_shape=jax.ShapeDtypeStruct((B, K, S), jnp.float32),
        interpret=interpret,
    )(q, k)
    return out


@functools.partial(JC.jit, static_argnames=("s_tile", "interpret"))
def head_score_varlen_call(
    q: jax.Array,     # [R, K, Rq, dh]  block queries per request, groups flat
    k: jax.Array,     # [K, T, dh]      flat packed-stream keys, head-major
    seg: jax.Array,   # [T] int32       ascending owner id (PAD_SEG on pad)
    *,
    s_tile: int = 512,
    interpret: bool = True,
):
    """Raw per-KV-head scores of every request against the FLAT stream:
    ``out[r, k, t] = max_q(Q_{r,q,k} · K_t)`` where ``seg[t] == r``, else
    ``-inf``. Replaces the padded per-request ``[R, max_seq_len]`` K gather
    of the packed Refresh path — selection reads the stream in place."""
    R, K, Rq, dh = q.shape
    T = k.shape[1]
    s_tile = min(s_tile, T)
    assert T % s_tile == 0, (T, s_tile)
    out = pl.pallas_call(
        _varlen_kernel,
        grid=(R, K, T // s_tile),
        in_specs=[
            pl.BlockSpec((1, 1, Rq, dh), lambda r, h, j: (r, h, 0, 0)),
            pl.BlockSpec((1, s_tile, dh), lambda r, h, j: (h, j, 0)),
            pl.BlockSpec((s_tile,), lambda r, h, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, 1, s_tile), lambda r, h, j: (r, h, j)),
        out_shape=jax.ShapeDtypeStruct((R, K, T), jnp.float32),
        interpret=interpret,
    )(q, k, seg)
    return out
