"""Sharded, mesh-independent checkpointing with async save + elastic restore.

Format: one directory per step —
  ``ckpt_<step>/manifest.json``  — tree structure, shapes, dtypes, step
  ``ckpt_<step>/arr_<i>.npy``    — one file per leaf (host-gathered)

Properties needed at 1000-node scale and implemented here:
  * **step-atomic**: written to a tmp dir, ``os.rename``d on completion, so a
    crash mid-save never corrupts the latest checkpoint;
  * **async**: device→host transfer happens on the caller thread (cheap,
    avoids racing donated buffers), file I/O on a background thread;
  * **elastic**: arrays are stored unsharded, so restore accepts *any* mesh /
    sharding layout — scaling from 256 to 512 chips (or to 1 CPU in tests)
    is a restore-time re-shard, no conversion step;
  * **GC**: keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import pickle
import shutil
import threading
from typing import Optional

import jax
import numpy as np


def save(path: str, step: int, state: dict, keep: int = 3,
         async_io: bool = True) -> threading.Thread | None:
    """state: any pytree (params/opt/rng/...). Returns the writer thread."""
    leaves, treedef = jax.tree.flatten(state)
    host = [np.asarray(jax.device_get(x)) for x in leaves]
    manifest = {
        "step": int(step),
        "treedef": pickle.dumps(treedef).hex(),
        "n_leaves": len(host),
        "shapes": [list(a.shape) for a in host],
        "dtypes": [str(a.dtype) for a in host],
    }

    def write():
        final = os.path.join(path, f"ckpt_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        for i, a in enumerate(host):
            np.save(os.path.join(tmp, f"arr_{i}.npy"), a)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(path, keep)

    if async_io:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _gc(path: str, keep: int) -> None:
    ckpts = sorted(d for d in os.listdir(path) if d.startswith("ckpt_")
                   and not d.endswith(".tmp"))
    for d in ckpts[:-keep]:
        shutil.rmtree(os.path.join(path, d), ignore_errors=True)


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    ckpts = sorted(d for d in os.listdir(path) if d.startswith("ckpt_")
                   and not d.endswith(".tmp"))
    if not ckpts:
        return None
    return int(ckpts[-1].split("_")[1])


def restore(path: str, step: Optional[int] = None, shardings=None):
    """Load a checkpoint; optionally re-shard onto a (new) mesh.

    ``shardings``: a pytree of Sharding matching the state (elastic restore),
    or None for host/default placement.
    """
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    d = os.path.join(path, f"ckpt_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    treedef = pickle.loads(bytes.fromhex(manifest["treedef"]))
    leaves = [np.load(os.path.join(d, f"arr_{i}.npy"))
              for i in range(manifest["n_leaves"])]
    state = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.tree.map(
            lambda a, s: jax.device_put(a, s), state, shardings)
    return int(manifest["step"]), state
