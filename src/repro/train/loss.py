"""Masked-diffusion training loss (LLaDA objective) with C1 chunking.

For each sequence a mask ratio u ~ U(lo, hi) is drawn; tokens are masked
i.i.d. with probability u and the cross-entropy on masked positions is
weighted by 1/u — the discrete-diffusion ELBO estimator. The CE itself runs
through ``lm_head.diffusion_loss``: token-axis chunks of ``loss_chunk`` so
the ``[T, V]`` logit tensor never materializes (the paper's C1 applied to
training).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.diffusion import mask_token_id
from repro.models import backbone as BB
from repro.models import lm_head as LM

AUX_COEF = 0.01


def corrupt(tokens: jax.Array, rng: jax.Array, cfg: ModelConfig,
            tc: TrainConfig) -> Tuple[jax.Array, jax.Array]:
    """Sample per-sequence mask ratios and mask tokens. Returns
    (corrupted [B,S], weights [B,S])."""
    B, S = tokens.shape
    k1, k2 = jax.random.split(rng)
    u = jax.random.uniform(k1, (B, 1), minval=tc.mask_ratio_min,
                           maxval=tc.mask_ratio_max)
    mask = jax.random.uniform(k2, (B, S)) < u
    corrupted = jnp.where(mask, mask_token_id(cfg.vocab_size), tokens)
    weights = mask.astype(jnp.float32) / u      # 1/t ELBO weighting
    return corrupted, weights


def loss_fn(params: dict, cfg: ModelConfig, tc: TrainConfig,
            tokens: jax.Array, rng: jax.Array,
            frontend: Optional[jax.Array] = None) -> Tuple[jax.Array, dict]:
    corrupted, weights = corrupt(tokens, rng, cfg, tc)
    h, aux = BB.train_forward(params, cfg, corrupted, frontend,
                              remat=tc.remat)
    if cfg.frontend_dim:
        h = h[:, cfg.frontend_len:]             # supervise the text region only
    ce = LM.diffusion_loss(params["embed"], cfg, h, tokens, weights,
                           chunk=tc.loss_chunk)
    loss = ce + AUX_COEF * aux
    return loss, {"ce": ce, "aux": aux}
