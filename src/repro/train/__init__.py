# Training substrate: masked-diffusion loss, AdamW + ZeRO-1, checkpointing,
# fault-tolerant train loop. Built from scratch (no optax/orbax available).
