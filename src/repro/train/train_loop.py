"""Fault-tolerant training loop: grad accumulation, remat, checkpoint/restart,
straggler detection, optional gradient compression.

``make_train_step`` builds the jitted step used both for real (tiny) training
in tests/examples and for the dry-run lowering of every assigned arch:

  grads = (1/M) Σ_microbatch ∇ loss      (lax.scan over M microbatches)
  params, opt = AdamW(params, grads)

Fault-tolerance contract (tested in tests/test_train.py):
  * checkpoint every ``ckpt_every`` steps (async, step-atomic),
  * ``run()`` resumes from the latest checkpoint if one exists — a crashed
    node restarting mid-run loses at most ``ckpt_every`` steps,
  * per-step wall-time deadline flags stragglers (at scale this triggers
    re-sharding / hot-spare swap; single-host we record the event and keep
    a running median).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import jax_compat as JC
from repro.configs.base import ModelConfig, TrainConfig
from repro.train import checkpoint as ckpt_lib
from repro.train.loss import loss_fn
from repro.train.optimizer import (adamw_update, compress_grads,
                                   init_opt_state)


def make_train_step(cfg: ModelConfig, tc: TrainConfig,
                    total_steps: int = 10_000) -> Callable:
    """Returns step(params, opt, tokens [G, S], rng) -> (params, opt, metrics).

    ``G = microbatches × per_step_batch``; the scan accumulates gradients so
    peak activation memory is one microbatch deep.
    """

    has_fe = bool(cfg.frontend_dim)

    def step(params, opt, tokens, rng, frontend=None):
        M = tc.microbatches
        G = tokens.shape[0]
        assert G % M == 0, (G, M)
        mb = tokens.reshape(M, G // M, tokens.shape[1])
        rngs = jax.random.split(rng, M)
        xs = (mb, rngs)
        if has_fe:
            xs = xs + (frontend.reshape((M, G // M) + frontend.shape[1:]),)

        gfn = jax.value_and_grad(loss_fn, has_aux=True)

        def accum(carry, xs):
            g_acc, loss_acc = carry
            tok, r = xs[0], xs[1]
            f = xs[2] if has_fe else None
            (loss, _metrics), grads = gfn(params, cfg, tc, tok, r, f)
            grads = compress_grads(grads, tc.grad_compression)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(a.dtype) / M, g_acc, grads)
            return (g_acc, loss_acc + loss / M), None

        acc_dtype = (jnp.bfloat16 if tc.grad_compression == "bf16"
                     else jnp.float32)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype), params)
        (grads, loss), _ = jax.lax.scan(accum, (g0, jnp.float32(0)), xs)
        grads = compress_grads(grads, tc.grad_compression)
        params, opt, om = adamw_update(params, grads, opt, tc, total_steps)
        om["loss"] = loss
        return params, opt, om

    if has_fe:
        return step

    def step_nofe(params, opt, tokens, rng):
        return step(params, opt, tokens, rng)

    return step_nofe


@dataclass
class TrainerEvents:
    stragglers: List[dict] = field(default_factory=list)
    restarts: int = 0
    checkpoints: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainConfig, ckpt_dir: str,
                 global_batch: int, seq_len: int, seed: int = 0,
                 total_steps: int = 1000, ckpt_every: int = 20,
                 straggler_factor: float = 3.0):
        from repro.models import backbone as BB
        self.cfg, self.tc = cfg, tc
        self.ckpt_dir = ckpt_dir
        self.global_batch, self.seq_len = global_batch, seq_len
        self.total_steps, self.ckpt_every = total_steps, ckpt_every
        self.straggler_factor = straggler_factor
        self.events = TrainerEvents()
        self.step_fn = JC.jit(make_train_step(cfg, tc, total_steps),
                              donate_argnums=(0, 1), entry="train_step")
        latest = ckpt_lib.latest_step(ckpt_dir)
        if latest is not None:
            self.start_step, state = ckpt_lib.restore(ckpt_dir)
            self.params, self.opt = state["params"], state["opt"]
            self.events.restarts += 1
        else:
            self.start_step = 0
            self.params = BB.init_params(cfg, jax.random.PRNGKey(seed))
            self.opt = init_opt_state(self.params)
        self.rng = jax.random.PRNGKey(seed + 17)

    def run(self, n_steps: int, data_fn: Callable[[int], np.ndarray],
            crash_at: Optional[int] = None, quiet: bool = True) -> List[dict]:
        """data_fn(step) -> tokens [G, S]. ``crash_at`` simulates a node
        failure (raises) for the restart test."""
        logs = []
        durations: List[float] = []
        pending_io = None
        for s in range(self.start_step, self.start_step + n_steps):
            if crash_at is not None and s == crash_at:
                raise RuntimeError(f"simulated node failure at step {s}")
            t0 = time.perf_counter()
            tokens = jnp.asarray(data_fn(s))
            self.rng, sub = jax.random.split(self.rng)
            self.params, self.opt, m = self.step_fn(
                self.params, self.opt, tokens, sub)
            # per-step metric readback is the train loop's sync point (the
            # step is donated, so the transfer cannot be deferred further)
            m = {k: float(v) for k, v in m.items()}  # lint: allow(host-sync)
            dt = time.perf_counter() - t0
            durations.append(dt)
            med = float(np.median(durations))
            if len(durations) > 5 and dt > self.straggler_factor * med:
                self.events.stragglers.append({"step": s, "dt": dt, "median": med})
            m.update(step=s, dt=dt)
            logs.append(m)
            if not quiet:
                print(f"step {s}: loss={m['loss']:.4f} dt={dt*1e3:.0f}ms")
            if (s + 1) % self.ckpt_every == 0:
                if pending_io is not None:
                    pending_io.join()
                pending_io = ckpt_lib.save(
                    self.ckpt_dir, s + 1,
                    {"params": self.params, "opt": self.opt})
                self.events.checkpoints += 1
        if pending_io is not None:
            pending_io.join()
        self.start_step += n_steps
        return logs
