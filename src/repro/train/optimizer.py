"""AdamW from scratch, with global-norm clipping, cosine schedule with
warmup, ZeRO-1-style moment sharding hooks, and gradient compression.

Gradient compression (``TrainConfig.grad_compression``):
  * ``bf16`` — gradients are cast to bf16 at the microbatch boundary, so the
    cross-replica reduce(-scatter) moves half the bytes. This is a *real*
    effect visible in the dry-run HLO collective sizes.
  * ``int8`` — per-tensor symmetric quantize→dequantize of the final
    gradient (simulated transport; XLA's implicit reductions cannot carry
    custom codecs — documented in DESIGN.md §8).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def init_opt_state(params) -> dict:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def lr_schedule(step: jax.Array, tc: TrainConfig,
                total_steps: int = 10_000) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - tc.warmup_steps)
                    / max(total_steps - tc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return tc.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def compress_grads(grads, mode: str):
    if mode == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    if mode == "int8":
        def q(g):
            g32 = g.astype(jnp.float32)
            scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-9) / 127.0
            return (jnp.round(g32 / scale).astype(jnp.int8)
                    .astype(jnp.float32) * scale).astype(g.dtype)
        return jax.tree.map(q, grads)
    return grads


def adamw_update(params, grads, opt, tc: TrainConfig,
                 total_steps: int = 10_000) -> Tuple[dict, dict, dict]:
    grads, gn = clip_by_global_norm(grads, tc.grad_clip)
    step = opt["step"] + 1
    lr = lr_schedule(step, tc, total_steps)
    b1, b2 = tc.beta1, tc.beta2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + tc.eps) + tc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, opt["m"], opt["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gn, "lr": lr}
