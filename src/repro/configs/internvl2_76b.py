"""internvl2-76b [arXiv:2404.16821; unverified] — InternViT + Llama3-70B backbone.

The InternViT-6B vision frontend is a STUB per the assignment:
`input_specs()` provides precomputed patch embeddings (`frontend_dim`) for
`frontend_len` positions; the 80-layer LM backbone is real.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128_256,
    head_dim=128,
    activation="silu",
    rope_theta=500_000.0,
    frontend_dim=3200,      # InternViT-6B hidden size (pre-projection)
    frontend_len=256,       # pixel-shuffled visual tokens per tile
)
