"""qwen2.5-14b [hf:Qwen/Qwen2.5 family; hf] — dense GQA(kv=8), QKV bias."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=152_064,
    head_dim=128,
    qkv_bias=True,
    activation="silu",
    rope_theta=1_000_000.0,
)
