"""musicgen-medium [arXiv:2306.05284; hf] — decoder-only over EnCodec tokens.

The EnCodec frontend is a STUB per the assignment: `input_specs()` provides
precomputed frame embeddings of `frontend_dim` at `frontend_len` positions;
the backbone (48L transformer, MHA) is real.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,          # MHA
    d_ff=6144,
    vocab_size=2048,        # EnCodec codebook size
    head_dim=64,
    activation="gelu",
    rope_theta=10_000.0,
    frontend_dim=1536,      # precomputed conditioning frame embeddings
    frontend_len=256,
)
