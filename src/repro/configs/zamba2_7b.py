"""zamba2-7b [arXiv:2411.15242; unverified] — hybrid: Mamba2 stack + shared attn block.

81 Mamba2 layers; one *shared* (weight-tied) attention+MLP block is applied
every `shared_attn_interval` layers (Zamba2's global shared transformer block).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,          # shared block is MHA
    d_ff=14336,             # shared block FFN
    vocab_size=32_000,
    head_dim=112,
    activation="gelu",
    ssm_state=64,
    ssm_head_dim=64,        # d_inner = 7168 -> 112 SSD heads
    ssm_expand=2,
    ssm_groups=1,
    ssm_conv_kernel=4,
    ssm_chunk=64,
    shared_attn_interval=6,
)
