"""mamba2-130m [arXiv:2405.21060; unverified] — SSD (state-space duality), attn-free."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,              # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_head_dim=64,        # d_inner = 2*768 = 1536 -> 24 SSD heads
    ssm_expand=2,
    ssm_groups=1,
    ssm_conv_kernel=4,
    ssm_chunk=64,
    tie_embeddings=True,
)
