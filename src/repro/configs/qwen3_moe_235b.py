"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3 family; hf] — MoE 128 experts top-8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,              # per-expert intermediate size
    vocab_size=151_936,
    head_dim=128,
    activation="silu",
    n_experts=128,
    experts_per_token=8,
    rope_theta=1_000_000.0,
)
