"""llada-8b — the paper's own evaluation model (LLaDA-8B-Instruct).

Llama-2-like backbone with bidirectional attention and a mask-predict head;
vocab 126,464 as used in the paper's §3.2 logit-boom arithmetic.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llada-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=12288,
    vocab_size=126_464,
    head_dim=128,
    activation="silu",
    rope_theta=500_000.0,
)
