"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct; hf] — MoE 16e top-2."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,              # per-expert intermediate size
    vocab_size=32_064,
    head_dim=128,
    activation="silu",
    n_experts=16,
    experts_per_token=2,
    rope_theta=10_000.0,
)
