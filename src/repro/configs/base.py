"""Config system for dLLM-Serve.

Three layers of config:
  * ModelConfig  — architecture hyperparameters (one per assigned arch).
  * ServeConfig  — the paper's serving knobs (max_num_batched_tokens,
                   max_num_logits, retention ratio, block size, ...).
  * ShapeConfig  — the assigned (seq_len, global_batch, kind) input shapes.

Everything is a frozen dataclass so configs hash and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # --- attention flavour -------------------------------------------------
    qkv_bias: bool = False
    activation: str = "silu"         # silu -> SwiGLU, gelu -> GeGLU
    attn_softcap: float = 0.0        # gemma2 logit softcapping (pre-softmax)
    final_softcap: float = 0.0       # gemma2 final-logit softcapping
    sliding_window: int = 0          # window size for local layers
    layer_pattern: str = "global"    # "global" | "alt_local_global"
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    moe_impl: str = "gather"         # gather (pjit baseline) | ep (shard_map EP)
    capacity_factor: float = 1.25
    # --- SSM (mamba2) --------------------------------------------------------
    ssm_state: int = 0               # N
    ssm_head_dim: int = 64           # P
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_groups: int = 1              # G (B/C groups)
    ssm_conv_kernel: int = 4
    ssm_chunk: int = 64              # SSD chunk length
    # --- hybrid (zamba2) ------------------------------------------------------
    shared_attn_interval: int = 0    # apply shared attn block every k layers
    # --- modality frontend stubs ----------------------------------------------
    frontend_dim: int = 0            # vlm/audio: dim of precomputed embeddings
    frontend_len: int = 0            # number of frontend positions in the seq
    # --- numerics ---------------------------------------------------------------
    dtype: str = "bfloat16"          # activation/param dtype for the dry-run

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def n_params(self) -> int:
        """Analytic parameter count (used for 6ND roofline math)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        dh = self.resolved_head_dim
        H, K = self.n_heads, self.n_kv_heads
        emb = V * D * (1 if self.tie_embeddings else 2)
        total = emb + D  # final norm
        if self.family == "ssm":
            total += L * self._ssm_layer_params()
            return total
        attn = D * H * dh + 2 * D * K * dh + H * dh * D
        if self.qkv_bias:
            attn += H * dh + 2 * K * dh
        if self.is_moe:
            mlp = self.n_experts * (3 * D * F) + D * self.n_experts
        else:
            mlp = 3 * D * F
        block = attn + mlp + 2 * D
        if self.family == "hybrid":
            # mamba2 stack + one shared attention+mlp block
            total += L * (self._ssm_layer_params() + D)
            shared_F = self.d_ff
            total += D * H * dh + 2 * D * K * dh + H * dh * D + 3 * D * shared_F + 2 * D
        else:
            total += L * block
        return total

    def _ssm_layer_params(self) -> int:
        D, Din = self.d_model, self.d_inner
        N, G, Hs = self.ssm_state, self.ssm_groups, self.ssm_heads
        conv_ch = Din + 2 * G * N
        in_proj = D * (2 * Din + 2 * G * N + Hs)
        return in_proj + conv_ch * (self.ssm_conv_kernel + 1) + 3 * Hs + Din + Din * D + D

    def n_active_params(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.n_params()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        dense = self.n_params() - L * self.n_experts * 3 * D * F
        return dense + L * self.experts_per_token * 3 * D * F


@dataclass(frozen=True)
class ServeConfig:
    """The paper's serving-system knobs (Table 3) plus TPU-port knobs."""
    max_num_batched_tokens: int = 4096   # scheduler query-token budget
    max_num_logits: int = 2048           # logit decomposition chunk (C1)
    block_size: int = 32                 # dLLM decode block B_size
    retention_ratio: float = 0.5         # sparse KV retention r (C3)
    kernel_size: int = 3                 # local max-pool window w
    refresh_interval: int = 8            # K_int: refresh cadence in steps
    steps_per_block: int = 32            # denoising steps per block
    max_seq_len: int = 512               # per-request L cap (slot KV region)
    max_slots: int = 16                  # concurrent request slots
    max_refresh_per_iter: int = 4        # refresh sub-batch bucket cap
    selection: str = "head"              # head | uniform | none (dense)
    scheduler: str = "phase"             # phase | request (baseline)
    logit_mode: str = "fused"            # fused (pallas) | chunked | monolithic
    varlen_pack: bool = False            # flatten inputs (no padding waste);
    # the paper's custom-engine contribution (§6.6 "Inference Engine"),
    # applied to the WHOLE iteration: Refresh runs ONE ragged token stream
    # instead of a padded [B, max_seq_len] batch, Reuse runs the active
    # blocks as one ragged [R·Sb] stream instead of a pow2 request batch,
    # and the logit stage decodes the real hidden rows at token_bucket
    # granularity instead of a pow2 row bucket. Every stage packs for EVERY
    # family: attention archs via the segment-masked varlen stream,
    # SSM/hybrid via the segment-reset varlen SSD scan, and vlm/audio via
    # frontend-prefix segments (projected frontend rows ride as a
    # fixed-length prefix of each request's Refresh segment).
    token_bucket: int = 128              # packed-stream size granularity
    # (rounds Σ Lᵢ up — bounds jit cache entries at budget/token_bucket while
    # keeping waste < one bucket, vs up-to-2× for power-of-two padding)
    use_flash_kernel: bool = False        # pallas attention in engine steps
    vocab_tile: int = 1024               # V-tile for the fused logit kernel
    dtype: str = "float32"
    # --- mesh serving (tensor-parallel packed pipeline) ----------------------
    mesh_shape: Optional[Tuple[int, ...]] = None
    # (data, model) device mesh the engine executes under. None = no mesh
    # (the single-device path, bit-identical to a 1×1 mesh). Under a mesh the
    # params are placed by ``launch.sharding.Rules.params``, the KV slot pool
    # is sharded by ``Rules.cache`` (KV heads over ``model`` when divisible,
    # retained-length fallback otherwise; the slot axis over ``data`` —
    # independent replica streams), every packed stage executes
    # tensor-parallel (vocab-parallel logit argmax included), and
    # ``plan_memory`` bills weights/activations/KV-slot bytes PER DEVICE.
    # The Pallas kernel paths shard_map themselves per model shard
    # (``kernels.ops``: head-sharded varlen attention/SSD scan,
    # vocab-sharded fused argmax with a cross-shard reduce); genuinely
    # indivisible head/vocab counts fail loudly at engine construction
    # (``launch.sharding.kernel_partition_plan``) — never a silent fallback.
    # --- memory-footprint multipliers (docs/memory.md) -----------------------
    # Both default OFF: the pool stays bit-exact per-request storage.
    prefix_sharing: bool = False         # content-addressed KV slot sharing:
    # requests whose Refresh capture hashes to already-resident content
    # become refcounted referrers of the owning slot (write skipped, gather
    # redirected, copy-on-write on the first divergent Refresh). Token
    # output is bit-identical to sharing-off — dedup only ever merges
    # provably identical bytes.
    kv_quant: str = "none"               # KV slot storage: "none" (bit-exact
    # float) | "int8" (per-(layer, slot) abs-max scales; the Reuse stages
    # dequantize at their KV load — kernels.ops.dequantize_gathered — so
    # pool HBM and the gather crossing stay int8). plan_memory converts the
    # smaller slot bytes into more concurrent slots.
    iter_log_cap: int = 0                # keep only the last N iter_log rows
    # (0 = unlimited — a long modeled-clock run otherwise accumulates one
    # dict per iteration forever, which a production engine cannot afford)
    # --- pipelined continuous-batching loop (docs/engine.md) -----------------
    clock: str = "wall"                  # "wall" (host time) | "modeled"
    # (virtual device clock — the discrete-event oracle; Engine's ``clock``
    # ctor arg overrides this field for back-compat)
    pipeline: bool = True                # dispatch-ahead serving loop: build
    # iteration i+1's IterationPlan/PackedIterationLayout while iteration i
    # executes on device, syncing i's ids/confidences only when i+1 has been
    # planned. Bit-identical to the synchronous loop (pipeline=False, the
    # oracle): the control plane — commit counts, block completion, phase
    # transitions, admission, preemption — is a function of lengths and
    # config only, never of the in-flight token VALUES, so deferring the
    # host sync cannot change any decision (proven by
    # tests/test_engine_pipeline.py).
    donate_buffers: bool = True          # donate per-iteration stream buffers
    # (token/position/validity streams, gathered reuse caches, the logit
    # stage's hidden rows) into their stage jits via donate_argnums, so the
    # packed streams stop double-buffering: each iteration's input buffers
    # are released (or aliased into outputs) the moment the stage consumes
    # them instead of living until the next host GC. Numerics are untouched
    # — donation only changes buffer lifetime.
    # --- robustness layer (admission control / shedding / preemption) --------
    # Defaults keep every knob OFF: unbounded queue, no deadlines enforced
    # beyond what requests carry, no preemption, 3 dispatch retries — the
    # no-faults configuration is bit-identical to the pre-robustness engine.
    queue_cap: int = 0                   # bounded waiting queue (0 = unbounded)
    queue_policy: str = "reject"         # "reject" new arrivals when full, or
    # "evict" the oldest waiter (it is shed with Outcome.SHED_QUEUE)
    preempt_starvation_s: float = 0.0    # preempt the youngest Reuse-phase
    # resident when the head waiter has starved this long with no free slot
    # (0 = preemption disabled)
    max_preemptions: int = 2             # per-request preemption cap (bounds
    # requeue thrash; a capped request simply finishes as a resident)
    fault_retries: int = 3               # dispatch attempts before a
    # FaultError becomes permanent (exponential backoff between attempts)

    @property
    def mesh_devices(self) -> int:
        """Total devices of ``mesh_shape`` (1 when no mesh is configured)."""
        n = 1
        for d in self.mesh_shape or ():
            n *= d
        return n

    @property
    def mesh_model(self) -> int:
        """Size of the tensor-parallel (``model``) axis; trailing mesh dim."""
        return self.mesh_shape[-1] if self.mesh_shape else 1

    @property
    def mesh_data(self) -> int:
        """Combined data-parallel axis size (all leading mesh dims)."""
        return self.mesh_devices // self.mesh_model

    @property
    def retained_len(self) -> int:
        return max(self.block_size, int(self.max_seq_len * self.retention_ratio))

    @property
    def refresh_slots(self) -> int:
        """Per-iteration Refresh cap with the ``0 = unlimited`` semantics
        normalized in ONE place: ``max_refresh_per_iter=0`` means no
        per-iteration cap beyond ``max_slots`` residency. Every consumer
        (scheduler admission, engine chunking, warmup bucket bounds, the
        profiler's padded-bucket accounting) must read this property — the
        raw field compared ``< 0`` livelocks the scheduler."""
        if self.max_refresh_per_iter > 0:
            return min(self.max_slots, self.max_refresh_per_iter)
        return self.max_slots


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode | long_decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The assigned input-shape set (identical for all 10 LM-family archs).
SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4_096, 256),
    ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    ShapeConfig("decode_32k", "decode", 32_768, 128),
    ShapeConfig("long_500k", "long_decode", 524_288, 1),
)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclass(frozen=True)
class TrainConfig:
    """Training-substrate knobs for train_step."""
    microbatches: int = 16               # grad-accumulation steps
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    remat: bool = True
    zero1: bool = True                   # shard Adam moments over data axis
    grad_compression: str = "none"       # none | bf16 | int8
    mask_ratio_min: float = 0.1          # masked-diffusion mask schedule
    mask_ratio_max: float = 1.0
    loss_chunk: int = 2048               # token-axis chunk for the CE (C1 in training)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        n_layers=max(2, min(cfg.n_layers, 3)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 4,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        sliding_window=8 if cfg.sliding_window else 0,
        n_experts=4 if cfg.n_experts else 0,
        experts_per_token=2 if cfg.n_experts else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=8,
        ssm_chunk=8,
        shared_attn_interval=2 if cfg.shared_attn_interval else 0,
        frontend_dim=32 if cfg.frontend_dim else 0,
        frontend_len=4 if cfg.frontend_len else 0,
        dtype="float32",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
