"""gemma2-27b [arXiv:2408.00118; hf] — local+global alternating, logit softcap."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab_size=256_000,
    head_dim=128,
    activation="gelu",          # GeGLU
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    layer_pattern="alt_local_global",
    tie_embeddings=True,
    rope_theta=10_000.0,
)
