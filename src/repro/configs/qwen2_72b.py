"""qwen2-72b [arXiv:2407.10671; hf] — dense GQA(kv=8), QKV bias. Largest dense."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152_064,
    head_dim=128,
    qkv_bias=True,
    activation="silu",
    rope_theta=1_000_000.0,
)
