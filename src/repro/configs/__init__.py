"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from repro.configs.base import (
    ModelConfig,
    ServeConfig,
    ShapeConfig,
    TrainConfig,
    SHAPES,
    SHAPES_BY_NAME,
    reduced,
)

from repro.configs.gemma_2b import CONFIG as _gemma_2b
from repro.configs.gemma2_27b import CONFIG as _gemma2_27b
from repro.configs.qwen25_14b import CONFIG as _qwen25_14b
from repro.configs.qwen2_72b import CONFIG as _qwen2_72b
from repro.configs.mamba2_130m import CONFIG as _mamba2_130m
from repro.configs.musicgen_medium import CONFIG as _musicgen_medium
from repro.configs.qwen3_moe_235b import CONFIG as _qwen3_moe
from repro.configs.phi35_moe import CONFIG as _phi35_moe
from repro.configs.zamba2_7b import CONFIG as _zamba2_7b
from repro.configs.internvl2_76b import CONFIG as _internvl2_76b
from repro.configs.llada_8b import CONFIG as _llada_8b

ARCHS = {
    "gemma-2b": _gemma_2b,
    "gemma2-27b": _gemma2_27b,
    "qwen2.5-14b": _qwen25_14b,
    "qwen2-72b": _qwen2_72b,
    "mamba2-130m": _mamba2_130m,
    "musicgen-medium": _musicgen_medium,
    "qwen3-moe-235b-a22b": _qwen3_moe,
    "phi3.5-moe-42b-a6.6b": _phi35_moe,
    "zamba2-7b": _zamba2_7b,
    "internvl2-76b": _internvl2_76b,
    # the paper's own model (not part of the assigned 10, used by examples)
    "llada-8b": _llada_8b,
}

ASSIGNED = tuple(k for k in ARCHS if k != "llada-8b")


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)


__all__ = [
    "ModelConfig", "ServeConfig", "ShapeConfig", "TrainConfig",
    "SHAPES", "SHAPES_BY_NAME", "ARCHS", "ASSIGNED",
    "get_config", "list_archs", "reduced",
]
