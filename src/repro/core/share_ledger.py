"""Content-addressed slot sharing: the refcount layer under KVPool.

Production dLLM traffic mostly repeats itself — shared system prompts,
duplicated prompts from retries and fan-out — and the slot-granular pool
bills that KV once **per request**. This module is the pure host-side half
of the fix: a block-chained content hash over the inputs that determine a
Refresh capture (:func:`block_chain_key`), and a :class:`ShareLedger`
mapping content keys to the one *owner* slot that physically holds the
bytes, with every other logical slot recorded as a *referrer* that
redirects its gathers to the owner.

Design (see ``docs/memory.md`` for the full contract):

* **Write-time dedup, reserved backing.** Every admitted request still
  owns a physical slot (scheduler admission arithmetic is untouched, so
  scheduling — and therefore token output — is bit-identical with sharing
  on or off). What sharing removes is the *write*: a Refresh whose content
  key already has an owner skips the device scatter and records a
  redirect instead. Savings show up as distinct-owner occupancy
  (``phys_slots`` < residents) and as skipped write bandwidth, and
  ``plan_memory`` converts the measured share factor into logical
  capacity.
* **Copy-on-write on divergence.** The first Refresh whose key differs
  from the slot's current key releases the old reference. If the slot
  *owned* content that others still reference, the content is promoted to
  the lowest-numbered referrer via one device row-copy before the
  diverging write lands — referrers never observe torn state.
* **Refcount-aware free.** ``KVPool.free`` routes through
  :meth:`ShareLedger.release`; freeing an owner with live referrers also
  promotes. Refcounts can never go below zero and a slot is never freed
  while referenced — the hypothesis suite (``tests/test_kv_share.py``)
  drives arbitrary interleavings against a model store.

The ledger is deliberately device-free (plain dicts/sets) so property
tests run thousands of interleavings without touching a jit; the device
copy a promote requires is returned to the caller (KVPool) as a
``(src, dst)`` pair to execute.
"""
from __future__ import annotations

import hashlib
import struct
from typing import Dict, Optional, Set, Tuple

import numpy as np


def block_chain_key(tokens: np.ndarray, block_size: int,
                    extra: bytes = b"") -> bytes:
    """Chained block hash of a token array: ``h_i = H(h_{i-1} || block_i)``.

    Hashing in ``block_size`` chunks keeps the digest a *prefix chain* —
    two sequences share the chain value after ``i`` blocks iff their first
    ``i`` blocks are identical — which is the natural granularity for a
    future sub-slot paged pool. The final chain value (xored into
    ``extra``-derived metadata by :func:`content_key`) addresses the whole
    slot. 128-bit blake2b: collisions are out of reach, and the e2e
    bit-identity suites would surface one loudly anyway.
    """
    t = np.ascontiguousarray(np.asarray(tokens, np.int32))
    bs = max(1, int(block_size))
    h = hashlib.blake2b(extra, digest_size=16)
    for off in range(0, t.size, bs):
        h = hashlib.blake2b(t[off: off + bs].tobytes(), key=h.digest(),
                            digest_size=16)
    return h.digest()


def content_key(tokens: np.ndarray, block_size: int, total_len: int,
                block_start: int, frontend: Optional[np.ndarray]) -> bytes:
    """Content address of one Refresh capture.

    Covers every input the captured cache is a deterministic function of:
    the full (padded) token array as a block chain, the live length and
    active-block offset (two requests with identical token bytes but
    different geometry must not collide), and the frontend payload for
    modality archs. Static config/params are engine-constant — keys are
    only ever compared within one engine.
    """
    meta = struct.pack("<qq", int(total_len), int(block_start))
    if frontend is not None:
        meta += hashlib.blake2b(
            np.ascontiguousarray(frontend).tobytes(),
            digest_size=16).digest()
    return block_chain_key(tokens, block_size, extra=meta)


class ShareLedger:
    """Host-side refcounted content→slot map (no device state).

    Invariants (property-tested in ``tests/test_kv_share.py``):

    * every tracked slot resolves to exactly one owner;
    * an owner's referrer set always contains the owner itself;
    * ``refcount(s) >= 1`` for every owner, 0 for untracked slots —
      never negative;
    * each content key has at most one owner (``slot_of`` is injective);
    * a promote only ever moves content to a *live referrer* of the old
      owner.
    """

    def __init__(self) -> None:
        self.owner_of: Dict[int, int] = {}      # any tracked slot -> owner
        self.referrers: Dict[int, Set[int]] = {}  # owner -> tracked slots
        self.key_of: Dict[int, bytes] = {}      # owner -> content key
        self.slot_of: Dict[bytes, int] = {}     # content key -> owner
        # counters (engine stats surface these)
        self.hits = 0            # writes deduplicated against a live owner
        self.cow_promotes = 0    # divergence/release promotes (device copies)

    # -- queries -----------------------------------------------------------
    def resolve(self, slot: int) -> int:
        """Physical slot whose bytes back ``slot``'s content."""
        return self.owner_of.get(slot, slot)

    def refcount(self, slot: int) -> int:
        """Number of logical slots backed by ``slot`` (0 = not an owner)."""
        return len(self.referrers.get(slot, ()))

    def is_shared_owner(self, slot: int) -> bool:
        """True when freeing ``slot`` would force a promote copy."""
        return len(self.referrers.get(slot, ())) > 1

    @property
    def phys_slots(self) -> int:
        """Distinct content-holding slots (the real occupancy)."""
        return len(self.key_of)

    # -- mutations ---------------------------------------------------------
    def _detach(self, slot: int) -> Optional[Tuple[int, int]]:
        """Drop ``slot``'s current reference (if any). Returns a
        ``(src, dst)`` device copy to execute when the detach orphans
        content that live referrers still need (promote-on-release)."""
        owner = self.owner_of.pop(slot, None)
        if owner is None:
            return None
        refs = self.referrers[owner]
        refs.discard(slot)
        if owner != slot:
            return None                    # plain referrer left; owner intact
        key = self.key_of.pop(owner)
        del self.slot_of[key]
        del self.referrers[owner]
        if not refs:
            return None                    # last holder gone; content dies
        # the owner's bytes outlive the owner: promote to the lowest
        # referrer (deterministic choice — shard_check compares pools
        # across runs) before the old slot is reused
        dst = min(refs)
        self.owner_of.update({s: dst for s in refs})
        self.referrers[dst] = refs
        self.key_of[dst] = key
        self.slot_of[key] = dst
        self.cow_promotes += 1
        return (owner, dst)

    def record_write(self, slot: int, key: bytes
                     ) -> Tuple[bool, Optional[Tuple[int, int]]]:
        """Account one Refresh capture of ``key`` into logical ``slot``.

        Returns ``(do_write, promote)``: ``do_write`` is False when the
        content is already resident under an owner (the caller redirects
        the device scatter to scratch), and ``promote`` is an optional
        ``(src, dst)`` row copy the caller must execute *before* the
        scatter lands (copy-on-write: the slot diverged while owning
        shared bytes).
        """
        if self.owner_of.get(slot) is not None and \
                self.key_of.get(self.resolve(slot)) == key:
            return False, None             # unchanged content, same backing
        promote = self._detach(slot)
        owner = self.slot_of.get(key)
        if owner is not None:
            self.owner_of[slot] = owner
            self.referrers[owner].add(slot)
            self.hits += 1
            return False, promote
        self.owner_of[slot] = slot
        self.referrers[slot] = {slot}
        self.key_of[slot] = key
        self.slot_of[key] = slot
        return True, promote

    def release(self, slot: int) -> Optional[Tuple[int, int]]:
        """Forget ``slot`` entirely (KVPool.free / eviction). Returns the
        promote copy to execute when the freed slot owned shared bytes."""
        return self._detach(slot)

    # -- integrity ---------------------------------------------------------
    def check(self) -> None:
        """Assert the full invariant set (test hook; cheap enough to call
        after every chaos iteration)."""
        for s, o in self.owner_of.items():
            assert o in self.referrers, (s, o)
            assert s in self.referrers[o], (s, o)
            assert self.owner_of.get(o) == o, (s, o)
        for o, refs in self.referrers.items():
            assert refs, o
            assert o in refs and o in self.key_of, (o, refs)
            for s in refs:
                assert self.owner_of.get(s) == o, (o, s)
        assert set(self.key_of) == set(self.referrers)
        for o, k in self.key_of.items():
            assert self.slot_of[k] == o, (o, k)
        assert len(self.slot_of) == len(self.key_of)
