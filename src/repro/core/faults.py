"""Deterministic fault-injection harness (chaos testing for the engine).

A :class:`FaultPlan` is a *seeded, finite schedule* of injected faults that
the engine consumes at well-defined sites inside :meth:`Engine.step`:

- ``dispatch`` — a jitted stage call (refresh / reuse / decode) raises
  :class:`FaultError`; the engine retries with exponential backoff on the
  modeled clock, up to ``ServeConfig.fault_retries`` attempts.
- ``alloc``    — the next ``count`` slot allocations fail transiently; the
  scheduler defers admission for the iteration (backpressure, no raise).
- ``mem``      — a memory-pressure event steals ``count`` free slots for
  ``duration`` iterations (shrinking effective capacity); if the waiting
  queue starves past the preemption threshold meanwhile, the normal
  preempt-to-reclaim path fires.
- ``slow``     — the iteration is delayed by ``delay_s`` (modeled clock:
  charged to vtime; wall clock: slept), perturbing arrival interleaving.

Everything is driven by an explicit event list or :meth:`FaultPlan.seeded`,
so a chaos run is exactly reproducible: the test suite asserts end-state
equivalence against the fault-free run (same token ids for every non-shed
request, zero leaked slots, ``submitted == finished + shed + rejected``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

KINDS = ("dispatch", "alloc", "mem", "slow")


class FaultError(RuntimeError):
    """An injected (or real) stage-dispatch failure."""


@dataclass(frozen=True)
class FaultEvent:
    kind: str               # one of KINDS
    at_iter: int            # engine iteration the event activates on
    count: int = 1          # dispatch: failures to inject; alloc: failed
                            # allocations; mem: slots stolen
    duration: int = 1       # mem: iterations the steal lasts
    delay_s: float = 0.0    # slow: added iteration latency (seconds)
    stage: str = "any"      # dispatch: restrict to refresh/reuse/decode

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


class FaultPlan:
    """Consumable fault schedule. One plan drives one engine run."""

    def __init__(self, events: List[FaultEvent]):
        self.events = sorted(events, key=lambda e: e.at_iter)
        self._cursor = 0
        self._iter = -1
        # live tokens
        self._dispatch: List[FaultEvent] = []   # pending dispatch failures
        self._alloc = 0                          # pending alloc failures
        self._mem: List[Tuple[int, int]] = []    # (slots_stolen, expires_iter)
        self._slow = 0.0                         # pending delay for this iter
        self.injected: Dict[str, int] = {k: 0 for k in KINDS}

    @classmethod
    def seeded(cls, seed: int, horizon: int = 200, n_events: int = 6,
               max_retries: int = 3) -> "FaultPlan":
        """Random-but-reproducible schedule. Dispatch bursts stay strictly
        below ``max_retries`` so a seeded chaos run degrades (retries,
        deferrals, delays) but never escalates to a permanent
        :class:`FaultError` — permanence is a deliberate, hand-built case."""
        rng = np.random.default_rng(seed)
        events = []
        for _ in range(n_events):
            kind = KINDS[int(rng.integers(len(KINDS)))]
            at = int(rng.integers(1, horizon))
            if kind == "dispatch":
                events.append(FaultEvent(
                    kind, at, count=int(rng.integers(1, max_retries)),
                    stage=("any", "refresh", "reuse",
                           "decode")[int(rng.integers(4))]))
            elif kind == "alloc":
                events.append(FaultEvent(kind, at,
                                         count=int(rng.integers(1, 4))))
            elif kind == "mem":
                events.append(FaultEvent(kind, at,
                                         count=int(rng.integers(1, 3)),
                                         duration=int(rng.integers(2, 8))))
            else:
                events.append(FaultEvent(
                    kind, at, delay_s=float(rng.uniform(0.01, 0.3))))
        return cls(events)

    # -- per-iteration protocol -------------------------------------------
    def begin_iteration(self, it: int) -> None:
        """Activate events scheduled at or before ``it``; expire mem steals."""
        self._iter = it
        self._slow = 0.0
        while self._cursor < len(self.events) and \
                self.events[self._cursor].at_iter <= it:
            ev = self.events[self._cursor]
            self._cursor += 1
            if ev.kind == "dispatch":
                self._dispatch.extend([ev] * ev.count)
            elif ev.kind == "alloc":
                self._alloc += ev.count
            elif ev.kind == "mem":
                self._mem.append((ev.count, it + ev.duration))
                self.injected["mem"] += 1
            else:
                self._slow += ev.delay_s
        self._mem = [(n, exp) for (n, exp) in self._mem if exp > it]

    def take_dispatch_fault(self, stage: str) -> bool:
        """Consume one pending dispatch failure for ``stage`` (or 'any')."""
        for i, ev in enumerate(self._dispatch):
            if ev.stage in ("any", stage):
                del self._dispatch[i]
                self.injected["dispatch"] += 1
                return True
        return False

    def take_alloc_fault(self) -> bool:
        """Consume one pending transient slot-allocation failure."""
        if self._alloc > 0:
            self._alloc -= 1
            self.injected["alloc"] += 1
            return True
        return False

    def stolen_slots(self) -> int:
        """Free slots currently held hostage by active mem-pressure events."""
        return sum(n for (n, _) in self._mem)

    def take_slow_delay(self) -> float:
        d, self._slow = self._slow, 0.0
        if d:
            self.injected["slow"] += 1
        return d

    def blocking(self) -> bool:
        """True while the plan can still suppress progress: pending alloc
        tokens, live mem steals, or any not-yet-activated event. The engine
        uses this to keep spinning (iteration count advances the schedule)
        instead of declaring a stall."""
        return (self._alloc > 0 or bool(self._mem)
                or self._cursor < len(self.events))
