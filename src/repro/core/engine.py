"""dLLM-Serve execution engine: continuous batching over Refresh/Reuse phases.

One engine iteration (§4.1 workflow):
  1. the scheduler builds an :class:`IterationPlan` under the query-token
     budget (C2),
  2. Refresh sub-batches run the full-seq forward + head-centric select/pack
     and write packed caches into the slot pool (C3),
  3. the Reuse set runs active-block attention over gathered slot caches,
  4. all block hidden states are decoded through the *budgeted* logit stage
     (C1: serial ``max_num_logits`` sub-batches / fused Pallas kernel),
  5. commits are applied host-side and request state machines advance.

Static-shape policy: two execution paths for the WHOLE iteration.

* padded (oracle): every stage is bucketed to powers of two — Refresh pads
  sequences to ``max_seq_len`` (plus the ``frontend_len`` prefix for
  vlm/audio), Reuse pads the request batch, and the logit stage pads the
  concatenated hidden rows — up to ~2× wasted FLOPs/HBM per stage. Kept
  purely as the correctness oracle: no family falls back to it on the hot
  path anymore.
* token-packed (``varlen_pack=True``, the paper's §4.1 flattened engine): no
  stage launches a pow2-padded rectangle for ANY family — attention archs
  run the segment-masked varlen attention stream, SSM/hybrid archs run the
  segment-reset varlen SSD scan (``kernels/ssm_scan``), and the
  modality-frontend archs (vlm/audio) pack their projected frontend rows as
  a fixed-length prefix of each request's segment. The iteration executes
  as a single packed pipeline driven by the scheduler's
  :class:`~repro.core.scheduler.PackedIterationLayout` (per-stage cu_seqlens):

    - Refresh: ONE ragged ``[T_total, ...]`` stream for the WHOLE iteration
      (``PackedIterationLayout.refresh_fused`` — a single fused dispatch
      across the refresh chunks), bucketed on *total tokens*
      (``token_bucket`` granularity; frontend prefix rows count), in-kernel
      segment masking + tile-skip (``kernels/flash_varlen``) or
      segment-reset state scan (``kernels/ssm_scan``), and select/pack that
      reads the stream in place (no padded K/V gather). vlm/audio segments
      are ``[frontend prefix ; text]``; Reuse and the logit stage address
      only the text region (block rows), so prefixes never enter them.
    - Reuse: the iteration's R active blocks form one ragged ``[R·Sb]``
      query stream (R rounded only to the token-bucket granularity) against
      their gathered slot caches — the cross-attention varlen kernel skips
      KV tiles of non-owned slots.
    - Logit stage: the real ``N`` hidden rows are decoded at token-bucket
      granularity with a validity mask threaded into the fused Pallas argmax
      kernel; all-padding chunks are never paid for.

  Per-stage ``*_tokens_real`` / ``*_tokens_exec`` counters expose the
  padding waste of each path (``refresh_waste`` / ``reuse_waste`` /
  ``logit_waste``).

Every jitted entry point is cached per bucket (padded: batch bucket; packed:
token/request-granularity bucket).

Mesh serving (``ServeConfig.mesh_shape``): the same pipeline executes
tensor-parallel under a (data, model) device mesh — params placed by
``launch.sharding.Rules.params``, the slot pool sharded by ``Rules.cache``,
per-stage PartitionSpecs threaded through the jitted entry points via
``repro.jax_compat.jit_sharded``, and the logit stage running vocab-parallel
(argmax/logsumexp reduce across vocab shards). The Pallas hot paths run
per-shard too: every stage dispatch happens inside the mesh context
(:meth:`Engine._mesh_ctx`) so the ``kernels.ops`` wrappers shard_map the
varlen attention / SSD scan over their local heads and the fused argmax over
the local vocab shard — kernels and tensor-parallelism compose. On a data
axis > 1 the slot pool shards its slot axis over ``data`` (independent
replica streams; the modeled clock credits the split). No mesh and a 1×1
mesh are
bit-identical to each other, so all padded-vs-packed oracles keep anchoring
correctness; the 1-vs-2-device agreement suite (``launch/shard_check.py``)
anchors the sharded path. See ``docs/sharding.md``.
"""
from __future__ import annotations

import contextlib
import itertools
import math
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import jax_compat as JC
from repro.jax_compat import P
from repro.configs.base import ModelConfig, ServeConfig
from repro.core import diffusion
from repro.core.budgeting import (admission_block_reason, can_pack_tokens,
                                  pow2_bucket as _bucket, token_bucket_round)
from repro.core.faults import FaultError, FaultPlan
from repro.kernels import flash_varlen as FV
from repro.kernels import ops as OPS
from repro.core.kv_pool import KVPool
from repro.core.request import Outcome, Phase, Request, State
from repro.core.scheduler import make_scheduler
from repro.launch.mesh import make_serving_mesh
from repro.models import backbone as BB
from repro.models import lm_head as LM
from repro.models import transformer as T


@dataclass(frozen=True)
class DeviceModel:
    """Virtual accelerator cost model for the modeled clock.

    A serial CPU cannot reward batching (compute scales with tokens), so
    wall-clock serving runs on this host cannot exhibit the paper's
    concurrency gains. In modeled-clock mode the engine still executes every
    step for real (functional fidelity) but advances a virtual clock by
    ``launch + padded_flops/peak`` per device call — the standard
    discrete-event methodology for serving-system studies. ``launch``
    captures per-step dispatch/sync overhead (dLLM denoising is a long
    sequential chain of small steps — exactly the regime where packing more
    work per step wins); ``peak`` is effective device throughput.

    Defaults are scaled to the reduced CPU models: the toy is ~4000× smaller
    than LLaDA-8B, so peak is scaled by the same factor (82 TF/4000 ≈ 20 GF/s)
    to preserve the real system's compute:launch ratio (Refresh steps
    compute-bound at ~100 ms, Reuse steps ~10 ms, launches ~1 ms).
    """
    launch_s: float = 1e-3
    peak_flops: float = 20e9

    def call_cost(self, flops: float, work_split: float = 1.0) -> float:
        """Virtual seconds for one device call. ``work_split`` is the factor
        by which the per-call FLOPs genuinely divide across devices — the
        engine passes its tensor-parallel work split (1.0 when nothing
        shards, up to the model-axis size when the matmul weights fully
        divide; launch overhead is paid once regardless, and serving
        implements no data parallelism so a data axis never contributes)."""
        return self.launch_s + flops / (self.peak_flops
                                        * max(1.0, work_split))


@dataclass
class EngineStats:
    iterations: int = 0
    refresh_steps: int = 0
    reuse_steps: int = 0
    committed_tokens: int = 0
    deferred_steps: int = 0
    peak_query_tokens: int = 0
    wall_time: float = 0.0
    # padded-vs-packed accounting, one pair per stage: `real` is the stage's
    # true token count (Σ refresh_len — frontend prefix + text — for
    # Refresh, R·Sb for Reuse, N hidden rows for the logit stage); `exec` is
    # what the device actually consumed (pow2 rectangles on the oracle path,
    # token-bucket rounding packed).
    refresh_tokens_real: int = 0
    refresh_tokens_exec: int = 0
    reuse_tokens_real: int = 0
    reuse_tokens_exec: int = 0
    logit_tokens_real: int = 0
    logit_tokens_exec: int = 0
    packed_refresh_calls: int = 0
    padded_refresh_calls: int = 0
    packed_reuse_calls: int = 0
    padded_reuse_calls: int = 0
    # -- request lifecycle / robustness accounting (docs/robustness.md) ----
    # Conservation law (asserted by the chaos suite): every submitted
    # request reaches exactly one terminal outcome —
    # ``submitted == finished + shed + rejected``.
    submitted: int = 0
    finished: int = 0
    rejected_oversized: int = 0
    rejected_queue_full: int = 0
    shed_deadline: int = 0
    shed_queue: int = 0
    preemptions: int = 0          # preempt-and-requeue events (not terminal)
    recomputed_tokens: int = 0    # commits discarded by preemption rollbacks
    dispatch_retries: int = 0     # transient dispatch faults absorbed
    # -- content-addressed slot sharing (docs/memory.md) -------------------
    shared_hits: int = 0          # Refresh writes deduplicated against a
    #                               resident owner slot (device write skipped)
    shared_cow_promotes: int = 0  # copy-on-write row promotes (divergent
    #                               Refresh or free of a still-referenced
    #                               owner)
    phys_slots_peak: int = 0      # high-water distinct-owner slot occupancy
    #                               (== peak residency when sharing is off)
    alloc_fault_iters: int = 0    # iterations whose admission hit an
    #                               injected slot-allocation failure
    slow_fault_s: float = 0.0     # injected slow-iteration delay absorbed
    # -- retrace sentinel (docs/analysis.md) -------------------------------
    # Per-entry-point XLA compilation counters (refresh/reuse/decode stage
    # jits + the pool scatter/gather), counted at trace time by the
    # ``jax_compat`` jit shims. ``compiles_warmup`` snapshots the total the
    # moment ``Engine.warmup`` returns; anything above it afterwards is a
    # steady-state recompilation — the static budget the retrace sentinel
    # (``repro.analysis.retrace``) holds at ZERO for a warmed engine.
    compile_counts: Dict[str, int] = field(default_factory=dict)
    compiles_warmup: int = 0
    # -- pipelined-loop host/device accounting (docs/engine.md) ------------
    # Wall-clock time, regardless of clock mode: the modeled clock prices
    # DEVICE work, while these measure the HOST side of the serving loop —
    # the gap the dispatch-ahead pipeline hides.
    host_plan_s: float = 0.0      # building IterationPlans + packed layouts
    host_fill_s: float = 0.0      # stage buffer fills + dispatch enqueue
    sync_wait_s: float = 0.0      # blocked in the deferred device_get
    overlapped_host_s: float = 0.0  # plan time spent while a previous
    #                                 iteration's dispatch was still in flight
    dispatched_ahead: int = 0     # iterations planned with a sync pending
    streamed_events: int = 0      # per-iteration commit events emitted to
    #                               the streaming callback
    # list when unlimited; the engine swaps in a maxlen deque under
    # ServeConfig.iter_log_cap (O(1) eviction of the oldest rows)
    iter_log: List[dict] = field(default_factory=list)

    @property
    def compiles_total(self) -> int:
        return sum(self.compile_counts.values())

    @property
    def compiles_post_warmup(self) -> int:
        """Compilations after the warmup snapshot (0 on a healthy warmed
        engine; equals ``compiles_total`` when warmup was never run)."""
        return self.compiles_total - self.compiles_warmup

    @property
    def overlap_frac(self) -> float:
        """Fraction of per-iteration host work (plan + fill) that ran while
        device work was in flight. Structural, not a wall-clock estimate:
        plan time counts as overlapped exactly when a dispatched iteration
        had not yet been synced — so the synchronous loop is identically 0
        and any dispatch-ahead shows up deterministically, even on hosts
        where timers are noisy (the CI gate relies on this)."""
        return self.overlapped_host_s / max(
            self.host_plan_s + self.host_fill_s, 1e-12)

    @property
    def rejected(self) -> int:
        return self.rejected_oversized + self.rejected_queue_full

    @property
    def shed(self) -> int:
        return self.shed_deadline + self.shed_queue

    def conserved(self) -> bool:
        """The lifecycle conservation law; True once the engine drains."""
        return self.submitted == self.finished + self.shed + self.rejected

    @property
    def refresh_waste(self) -> float:
        """exec/real token ratio (1.0 = zero padding waste)."""
        return self.refresh_tokens_exec / max(self.refresh_tokens_real, 1)

    @property
    def reuse_waste(self) -> float:
        return self.reuse_tokens_exec / max(self.reuse_tokens_real, 1)

    @property
    def logit_waste(self) -> float:
        return self.logit_tokens_exec / max(self.logit_tokens_real, 1)

    @property
    def throughput(self) -> float:
        return self.committed_tokens / max(self.wall_time, 1e-9)


@dataclass
class _CommitEntry:
    """One request's dispatched-but-unsynced commit (docs/engine.md).

    Recorded when the control plane advances at dispatch time; holds
    everything the deferred sync needs to land the token VALUES later: the
    hidden-row index, the block coordinates as of dispatch (the state
    machine has already moved on), the commit width, and the request's
    ``commit_epoch`` — a preemption rollback bumps the epoch, so a stale
    entry's values are dropped at sync (the rollback already booked those
    commits as recompute debt)."""
    req: Request
    row: int                  # request index in the decoded hidden stream
    block_start: int          # absolute offset of the committed block
    block_idx: int            # block index at dispatch (stream events)
    n_commit: int             # commit width passed to commit_tokens
    n_act: int                # positions actually unmasked (stats delta)
    epoch: int                # req.commit_epoch at dispatch
    finished: bool            # this commit completed the request
    t: float                  # commit timestamp (modeled vtime / wall now)


@dataclass
class _Prepared:
    """Host-side output of :meth:`Engine._begin_iteration`: one iteration's
    scheduler plan + packed layout, built as pure host work — the part the
    pipelined loop overlaps with in-flight device execution."""
    now: float
    plan: object              # IterationPlan
    layout: object            # PackedIterationLayout | None
    lifecycle: bool           # the plan shed/rejected/preempted something
    plan_s: float             # host seconds spent planning

    @property
    def has_exec(self) -> bool:
        return self.plan.has_exec


@dataclass
class _Pending:
    """One dispatched-but-unsynced iteration: the decode outputs still on
    device plus the commit entries to apply at the single deferred sync."""
    ids: jax.Array
    conf: jax.Array
    n_rows: int
    entries: List[_CommitEntry]
    log_row: dict


class Engine:
    def __init__(self, cfg: ModelConfig, serve: ServeConfig,
                 params: Optional[dict] = None, seed: int = 0,
                 clock: Optional[str] = None,
                 device_model: Optional[DeviceModel] = None,
                 faults: Optional[FaultPlan] = None,
                 stream_cb=None):
        self.cfg = cfg
        self.serve = serve
        # clock mode: the ctor arg (back-compat spelling every harness uses)
        # overrides ServeConfig.clock; None defers to the config
        self.clock = clock if clock is not None else serve.clock
        if self.clock not in ("wall", "modeled"):
            raise ValueError(f"Engine clock must be 'wall' or 'modeled', "
                             f"got {self.clock!r}")
        # streaming per-iteration token output (docs/engine.md): called once
        # per committed (request, iteration) at sync time — when the values
        # exist host-side — with a dict event; finished blocks surface
        # before the run completes instead of only via output_tokens()
        self._stream_cb = stream_cb
        self.faults = faults
        self.device = device_model or DeviceModel()
        self.vtime = 0.0
        self._n_params = cfg.n_active_params()
        if params is None:
            params = BB.init_params(cfg, jax.random.PRNGKey(seed))
        self.mask_id = diffusion.mask_token_id(cfg.vocab_size)
        retain = min(serve.retained_len,
                     serve.max_seq_len - serve.block_size)
        self.ctx = T.ServeContext(
            block_size=serve.block_size, retain=retain,
            kernel_size=serve.kernel_size, selection=serve.selection,
            q_chunk=min(T.L.DEFAULT_Q_CHUNK, serve.max_seq_len),
            use_flash_kernel=serve.use_flash_kernel,
            max_seq_len=serve.max_seq_len)
        # ---- device mesh (tensor-parallel serving) -----------------------
        # mesh_shape=(data, model): params placed by Rules.params, the slot
        # pool sharded by Rules.cache, every stage jitted with per-stage
        # PartitionSpecs (repro.jax_compat.jit_sharded). No mesh / 1×1 mesh
        # executes the identical computation — the single-device path is the
        # bit-identical anchor for all padded-vs-packed oracles.
        #
        # The Pallas hot paths shard-map themselves per model shard (see
        # kernels.ops): validate the head/vocab divisibility law up front —
        # before the mesh is even built, so indivisible configs fail loudly
        # without needing the devices — instead of silently falling back.
        if serve.mesh_model > 1 and (serve.use_flash_kernel
                                     or serve.logit_mode == "fused"):
            from repro.launch.sharding import kernel_partition_plan
            kernel_partition_plan(cfg, serve)
        # memory-footprint multipliers (docs/memory.md): validate up front so
        # an unsupported combination fails at construction, never silently
        # serves a different storage mode than the config asked for
        if serve.kv_quant not in ("none", "int8"):
            raise ValueError(f"ServeConfig.kv_quant must be 'none' or "
                             f"'int8', got {serve.kv_quant!r}")
        if serve.kv_quant != "none" and serve.mesh_shape is not None:
            raise NotImplementedError(
                "kv_quant='int8' is not yet composed with mesh serving — "
                "the quantized pool's scale leaves need their own "
                "Rules.cache-derived placement (see docs/memory.md)")
        self.mesh = make_serving_mesh(serve.mesh_shape)
        self.mesh_devices = self.mesh.devices.size if self.mesh else 1
        pool_shardings = gather_shardings = None
        self._pool_pad = 0
        if self.mesh is not None:
            from functools import partial as _partial

            from repro.launch.sharding import Rules
            self.rules = Rules(cfg, self.mesh, train=False)
            pshapes = jax.eval_shape(_partial(BB.init_params, cfg),
                                     jax.random.PRNGKey(0))
            self._pspecs = self.rules.params(pshapes)
            params = jax.device_put(params, self.rules.named(self._pspecs))
            # ONE cache layout for every *stream* — gathered sub-batches and
            # fresh Refresh caches (data_parallel=False: only the model axis
            # shards within a slot) — batch-size-dependent specs would
            # diverge across stages and break the in_shardings contract.
            # The slot POOL additionally shards its slot axis over the data
            # axis (slot_data_parallel): each of the mesh_data replica
            # streams stores its slots locally, so a (d, m) mesh holds d×
            # the slots of one device pair. Pad the pool's slot count up so
            # the axis always divides; writes scatter replicated caches into
            # the sharded pool and gathers land back in the stream layout.
            self._cache_spec = self.rules.cache(serve.max_slots + 1, retain,
                                                data_parallel=False)
            self._pool_pad = (-(serve.max_slots + 1)) % max(1, serve.mesh_data)
            self._pool_spec = self.rules.cache(
                serve.max_slots + 1 + self._pool_pad, retain,
                data_parallel=False, slot_data_parallel=True)
            pool_shardings = self.rules.named(self._pool_spec)
            gather_shardings = self.rules.named(self._cache_spec)
            # serving activation-sharding policy: replicate the token streams
            # at stage boundaries (weights/heads/vocab carry the TP sharding)
            # and pin the head weight vocab-parallel at its point of use so
            # the logit stage computes [N, V/TP] shards with the argmax
            # reducing across them. NamedSharding leaves (not bare specs):
            # the engine's jits don't run under a mesh context manager.
            from repro.models import layers as Lmod
            v_ax = self.rules.div(cfg.vocab_size)
            Lmod.set_sharding_policy(self.rules.named({
                "act3d": P(None, None, None),
                "packed_h": P(None, None),
                "logit_w": P(None, v_ax),
                "logit_w_tied": P(v_ax, None),
            }))
        else:
            self.rules = None
            self._pspecs = None
            # the policy is process-global: a later single-device engine must
            # not trace against a previous mesh engine's stale NamedShardings
            # (the newest engine owns the policy — one serving mesh per
            # process; the dryrun/train launchers set their own in their
            # own processes and never construct an Engine)
            from repro.models import layers as Lmod
            Lmod.set_sharding_policy({})
        self.params = params
        self.scheduler = make_scheduler(serve)
        # retrace sentinel: every jit entry point of THIS engine (stage jits
        # + the pool scatter/gather) counts its compilations here, so the
        # post-warmup compile budget is per-engine, not process-global
        from collections import Counter
        self._compile_counter: Counter = Counter()
        self.pool = KVPool(serve.max_slots, shardings=pool_shardings,
                           gather_shardings=gather_shardings,
                           pad_slots=self._pool_pad,
                           compile_counter=self._compile_counter,
                           sharing=serve.prefix_sharing,
                           kv_quant=serve.kv_quant,
                           donate_cache=serve.donate_buffers)
        self._sharing = serve.prefix_sharing
        # robustness wiring: the scheduler drives the pool's take/free
        # generation ledger on admit/finish/preempt, and consumes the fault
        # plan's alloc-failure / mem-steal tokens at admission time
        self.scheduler.pool = self.pool
        self.scheduler.faults = faults
        self._iter = 0              # engine iteration counter (fault schedule)
        self._fault_blocked = False  # last plan suppressed by injected faults
        self.stats = EngineStats()
        if serve.iter_log_cap:
            from collections import deque
            self.stats.iter_log = deque(maxlen=serve.iter_log_cap)
        # modeled-clock TP work split: credit only the fraction of per-token
        # work that ACTUALLY shards (same exact-division law the memory
        # planner bills by) — total/per-device param bytes on a pure-TP
        # (1, model) mesh is 1.0 when nothing divides and approaches
        # mesh_model as the matmul weights shard, so an indivisible mesh
        # can never fake a modeled speedup.
        if serve.mesh_model > 1:
            from repro.core.budgeting import weight_bytes_per_device
            self._tp_work_split = (
                weight_bytes_per_device(cfg, None)
                / max(1, weight_bytes_per_device(cfg, (1, serve.mesh_model))))
        else:
            self._tp_work_split = 1.0
        # data-axis replica credit: the slot pool shards its slot axis over
        # ``data`` (above), so a (d, m) mesh carries d independent replica
        # streams of the serving state — the modeled clock credits the full
        # d× on top of the actually-sharded TP fraction.
        self._dp_work_split = (float(serve.mesh_data)
                               if self.mesh is not None
                               and serve.mesh_data > 1 else 1.0)
        # modality-frontend prefix rows per request (0 for text-only archs):
        # every Refresh geometry below spans frontend_len + text rows, and
        # block/reuse positions are offset by it (full-sequence coordinates).
        self._fe_len = cfg.frontend_len if cfg.frontend_dim else 0
        # token-packed execution covers every family (segment-masked
        # attention stream, segment-reset SSD scan, or frontend-prefix
        # segments); same predicate the offline profiler bills activations
        # by — can_pack_tokens is the single opt-out point.
        self._use_packed = serve.varlen_pack and can_pack_tokens(cfg)
        self._refresh_jit: Dict[int, callable] = {}
        self._refresh_packed_jit: Dict[tuple, callable] = {}
        self._reuse_jit: Dict[int, callable] = {}
        self._reuse_packed_jit: Dict[int, callable] = {}
        self._decode_jit: Dict[int, callable] = {}
        self._decode_packed_jit: Dict[int, callable] = {}
        # rng only feeds synthetic frontend payload stand-ins; request ids
        # come from a monotonic counter (rng-drawn rids could collide and
        # silently merge two requests' stats)
        self._rng = np.random.default_rng(seed)
        self._rid_counter = itertools.count()

    @property
    def tp_work_split(self) -> float:
        """Factor by which per-token work genuinely divides across the TP
        axis (1.0 ≤ split ≤ model-axis size; the modeled clock and the
        per-device token metrics both use it)."""
        return self._tp_work_split

    @property
    def work_split(self) -> float:
        """Total modeled work division: the TP fraction × the data-axis
        replica streams (slot pool sharded over ``data``)."""
        return self._tp_work_split * self._dp_work_split

    @property
    def kernels_active(self) -> bool:
        """True when the Pallas hot paths are live in this engine — under a
        model axis > 1 they dispatch per-shard (shard_map), validated at
        construction; there is no silent jnp fallback."""
        return bool(self.serve.use_flash_kernel
                    or self.serve.logit_mode == "fused")

    # ------------------------------------------------------------------
    # jitted step functions (cached per bucket size)
    # ------------------------------------------------------------------
    def _donate(self, *argnums: int) -> tuple:
        """Per-iteration stream buffers are single-use: every dispatch builds
        fresh device inputs (``jnp.asarray`` of numpy fills, a fresh pool
        gather) that are dead the moment the call returns, so under
        ``ServeConfig.donate_buffers`` they are donated and XLA reuses their
        storage for the outputs instead of double-buffering the packed
        streams. Params (argnum 0) are never donated. Donation is a
        lifetime hint only — numerics are bit-identical either way — so the
        oracle suites run unchanged with it on or off."""
        return tuple(argnums) if self.serve.donate_buffers else ()

    def _stage_specs(self, n_stream: int, with_cache: bool = False):
        """in_specs for one stage entry point: params carry their Rules
        placement, token/offset streams replicate (the serving mesh's model
        axis shards weights/heads/vocab, not tokens), and gathered caches
        carry the slot pool's one fixed layout. None when no mesh is
        configured (plain ``jax.jit``)."""
        if self.mesh is None:
            return None
        in_specs = (self._pspecs,) + (P(),) * n_stream
        if with_cache:
            in_specs += (self._cache_spec,)
        return in_specs

    def _refresh_out_specs(self):
        """Pin Refresh outputs: block hidden replicated, the captured cache
        already in the slot pool's ``Rules.cache`` layout (so the pool write
        is a sharded scatter, never a reshard)."""
        if self.mesh is None:
            return None
        return BB.RefreshOut(block_hidden=P(), cache=self._cache_spec)

    def _refresh_fn(self, n: int):
        if n not in self._refresh_jit:
            ctx = self.ctx

            def fn(params, tokens, token_valid, block_start, frontend):
                return BB.serve_refresh(params, self.cfg, tokens, block_start,
                                        ctx, frontend=frontend,
                                        token_valid=token_valid)

            in_specs = self._stage_specs(4)
            self._refresh_jit[n] = JC.jit_sharded(
                fn, mesh=self.mesh, in_specs=in_specs,
                out_specs=self._refresh_out_specs(),
                donate_argnums=self._donate(1, 2),
                entry="refresh", counter=self._compile_counter)
        return self._refresh_jit[n]

    def _token_bucket(self, n_tokens: int) -> int:
        """Round a real token count up to the packed-buffer granularity."""
        tb = max(1, self.serve.token_bucket)
        return max(tb, -(-n_tokens // tb) * tb)

    def _reuse_bucket(self, n_requests: int) -> int:
        """Packed-Reuse request-count granularity: R·block_size rounded to
        the token bucket (``rb = token_bucket // Sb`` whole blocks — never a
        pow2 batch bucket). Below one bucket the stream runs exactly-sized:
        R is already capped by ``max_slots``, so sub-bucket shapes add at
        most ``rb`` jit entries and the packed dispatch never pays more
        tokens than the pow2 oracle (see ``token_bucket_round``)."""
        rb = max(1, self.serve.token_bucket // self.serve.block_size)
        return token_bucket_round(n_requests, rb)

    def _logit_bucket(self, n_rows: int) -> int:
        """Packed logit-stage granularity: hidden rows arrive in whole
        blocks (N = n_decoded·Sb), so below one token bucket the stream runs
        exactly-sized (≤ token_bucket/Sb extra jit entries); above, it
        rounds to token-bucket multiples. Never a pow2 row bucket."""
        return token_bucket_round(n_rows, self.serve.token_bucket)

    def _refresh_packed_fn(self, tp: int, rp: int):
        if (tp, rp) not in self._refresh_packed_jit:
            ctx = self.ctx

            def fn(params, flat_tokens, positions, seg_ids, token_valid,
                   cu_seqlens, seq_lens, block_start, frontend):
                return BB.serve_refresh_packed(
                    params, self.cfg, flat_tokens, positions, seg_ids,
                    token_valid, cu_seqlens, seq_lens, block_start, ctx,
                    frontend=frontend)

            in_specs = self._stage_specs(8)
            self._refresh_packed_jit[(tp, rp)] = JC.jit_sharded(
                fn, mesh=self.mesh, in_specs=in_specs,
                out_specs=self._refresh_out_specs(),
                donate_argnums=self._donate(1, 2, 3, 4),
                entry="refresh_packed", counter=self._compile_counter)
        return self._refresh_packed_jit[(tp, rp)]

    def _reuse_fn(self, n: int):
        if n not in self._reuse_jit:
            ctx = self.ctx

            def fn(params, block_tokens, block_positions, cache):
                # KV-load dequant point: under kv_quant the gathered view is
                # still int8 + scales; scaling back happens inside THIS jit
                # (jnp on the padded oracle path), never as pool state
                cache = OPS.dequantize_gathered(cache, self.serve.kv_quant,
                                                self.pool.gathered_dtypes)
                return BB.serve_reuse(params, self.cfg, block_tokens,
                                      block_positions, cache, ctx)

            in_specs = self._stage_specs(2, with_cache=True)
            self._reuse_jit[n] = JC.jit_sharded(
                fn, mesh=self.mesh, in_specs=in_specs,
                donate_argnums=self._donate(1, 2, 3),
                entry="reuse", counter=self._compile_counter)
        return self._reuse_jit[n]

    def _reuse_packed_fn(self, rp: int):
        if rp not in self._reuse_packed_jit:
            ctx = self.ctx

            def fn(params, flat_tokens, flat_positions, cache):
                # same KV-load dequant as the padded oracle — here it fuses
                # into the varlen cross-attention kernel's program
                cache = OPS.dequantize_gathered(cache, self.serve.kv_quant,
                                                self.pool.gathered_dtypes)
                return BB.serve_reuse_packed(params, self.cfg, flat_tokens,
                                             flat_positions, cache, ctx)

            in_specs = self._stage_specs(2, with_cache=True)
            self._reuse_packed_jit[rp] = JC.jit_sharded(
                fn, mesh=self.mesh, in_specs=in_specs,
                donate_argnums=self._donate(1, 2, 3),
                entry="reuse_packed", counter=self._compile_counter)
        return self._reuse_packed_jit[rp]

    def _decode_fn(self, n: int):
        if n not in self._decode_jit:
            serve = self.serve

            def fn(params, h):
                # vocab-parallel under a mesh: the head weight stays sharded
                # over vocab (Rules placement) so each device computes its
                # vocab shard's logits and the argmax/logsumexp reduce across
                # shards — the full [N, V] never gathers onto one device.
                return LM.decode_tokens(
                    params["embed"], self.cfg, h,
                    max_num_logits=serve.max_num_logits,
                    mode=serve.logit_mode, vocab_tile=serve.vocab_tile)

            in_specs = self._stage_specs(1)
            self._decode_jit[n] = JC.jit_sharded(
                fn, mesh=self.mesh, in_specs=in_specs,
                donate_argnums=self._donate(1),
                entry="decode", counter=self._compile_counter)
        return self._decode_jit[n]

    def _decode_packed_fn(self, n: int):
        if n not in self._decode_packed_jit:
            serve = self.serve

            def fn(params, h, valid):
                return LM.decode_tokens_packed(
                    params["embed"], self.cfg, h, valid,
                    max_num_logits=serve.max_num_logits,
                    mode=serve.logit_mode, vocab_tile=serve.vocab_tile)

            in_specs = self._stage_specs(2)
            self._decode_packed_jit[n] = JC.jit_sharded(
                fn, mesh=self.mesh, in_specs=in_specs,
                donate_argnums=self._donate(1, 2),
                entry="decode_packed", counter=self._compile_counter)
        return self._decode_packed_jit[n]

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def warmup(self) -> float:
        """Pre-compile every bucketed step function (refresh/reuse/decode and
        the pool scatter/gather) with dummy inputs — the AOT warmup any
        production serving system performs before accepting traffic.

        Bucket bounds are audited against what the runtime can actually
        request (the invariant ``tests/test_engine.py`` asserts): every
        cap reads the NORMALIZED ``ServeConfig.refresh_slots`` (so
        ``max_refresh_per_iter=0`` warms up to the ``max_slots``-wide fused
        dispatch instead of nothing) and every doubling loop runs until it
        has covered the pow2 bucket of the cap (``b <= cap`` stopped short
        of ``pow2_bucket(cap)`` for non-pow2 caps, leaving the worst-case
        compile to fire mid-serve). Sub-worst-case buckets still compile
        lazily — only the largest shape per stage is guaranteed AOT.
        Returns the compile wall-time so harnesses can report it."""
        t0 = time.perf_counter()
        # warm under the same mesh context the dispatch path uses: the
        # Pallas wrappers consult the active mesh at trace time to
        # shard_map themselves per model shard
        with self._mesh_ctx():
            self._warmup_compile()
        # retrace-sentinel snapshot: everything compiled so far is warmup;
        # any compile-counter growth beyond this point is a steady-state
        # recompilation (the budget repro.analysis.retrace holds at zero)
        self.stats.compiles_warmup = sum(self._compile_counter.values())
        self.stats.compile_counts = dict(self._compile_counter)
        return time.perf_counter() - t0

    def _warmup_compile(self) -> None:
        S, Sb = self.serve.max_seq_len, self.serve.block_size
        F = self._fe_len
        r_eff = self.serve.refresh_slots

        def _fe(b):
            """Dummy frontend batch (None for text-only archs)."""
            if not F:
                return None
            return jnp.zeros((b, F, self.cfg.frontend_dim), jnp.float32)
        # the fused packed dispatch spans the WHOLE plan.refresh: the phase
        # scheduler caps that at refresh_slots, but the request-level
        # baseline admits whole batches up to max_slots and relies on the
        # engine to absorb them (serial chunks padded, one fused stream
        # packed) — warm the fused bucket to the scheduler's true bound.
        r_fused = r_eff if self.serve.scheduler == "phase" \
            else self.serve.max_slots
        if self._use_packed:
            # packed path: warm the worst-case (token bucket, request bucket)
            # per refresh fused-dispatch size; smaller buckets compile lazily.
            # Per-request segments span frontend prefix + text (S + F rows),
            # and the scheduler budget caps the stream either way.
            b = 1
            while True:
                tp = self._token_bucket(
                    min(b * (S + F), self.serve.max_num_batched_tokens))
                out = self._refresh_packed_fn(tp, b)(
                    self.params, jnp.zeros((tp,), jnp.int32),
                    jnp.zeros((tp,), jnp.int32),
                    jnp.zeros((tp,), jnp.int32),
                    jnp.ones((tp,), bool),
                    jnp.zeros((b,), jnp.int32),
                    jnp.full((b,), min(tp, S + F), jnp.int32),
                    jnp.zeros((b,), jnp.int32),
                    _fe(b))
                # warm the pool scatter at this bucket's batch shape too —
                # the runtime writes a slot list of exactly rp entries after
                # every refresh, so an ensure()-only warmup leaves pool_write
                # to compile mid-serve (the retrace sentinel catches this).
                # Scatter ZEROS: the dummy refresh output is mesh-dependent
                # numerics, and depositing it in the scratch slot would break
                # the 1-vs-N-device pool agreement oracle (shard_check)
                self.pool.write([self.pool.scratch_slot] * b,
                                jax.tree.map(jnp.zeros_like, out.cache))
                if b >= _bucket(r_fused):
                    break
                b *= 2
        # fresh dummy arrays per call, never a broadcast view of a shared
        # template: the stage jits donate their stream buffers, and a
        # same-shape broadcast can alias its source — reusing the template
        # after a donating call would read a dead buffer
        b = 1
        while not self._use_packed:
            out = self._refresh_fn(b)(
                self.params, jnp.zeros((b, S), jnp.int32),
                jnp.ones((b, F + S), bool),
                jnp.zeros((b,), jnp.int32), _fe(b))
            self.pool.write([self.pool.scratch_slot] * b,
                            jax.tree.map(jnp.zeros_like, out.cache))
            if b >= _bucket(r_eff):
                break
            b *= 2
        # auxiliary pool jit (COW promote copy) — warmed here so a sharing
        # pool's first divergence/free-while-shared never compiles mid-serve
        # (no-op without sharing); the refresh loops above materialized the
        # pool, so the copy compiles at its real shapes
        self.pool.warm_aux()
        r_cap = max(1, min(self.serve.max_slots,
                           self.serve.max_num_batched_tokens // Sb))
        if self._use_packed:
            # packed Reuse: buckets are token_bucket-granular request counts
            # (doubling warm; intermediate multiples compile lazily)
            rp = self._reuse_bucket(1)
            while True:
                cache = self.pool.gather([self.pool.scratch_slot] * rp)
                self._reuse_packed_fn(rp)(
                    self.params, jnp.zeros((rp * Sb,), jnp.int32),
                    jnp.zeros((rp * Sb,), jnp.int32), cache)
                if rp >= self._reuse_bucket(r_cap):
                    break
                rp = min(rp * 2, self._reuse_bucket(r_cap))
        else:
            b = 1
            while True:
                cache = self.pool.gather([self.pool.scratch_slot] * b)
                self._reuse_fn(b)(self.params,
                                  jnp.zeros((b, Sb), jnp.int32),
                                  jnp.zeros((b, Sb), jnp.int32), cache)
                if b >= _bucket(r_cap):
                    break
                b *= 2
        max_logits = (r_eff + self.serve.max_slots) * Sb
        dt = jnp.dtype(self.cfg.dtype)
        if self.serve.varlen_pack:
            n = self._logit_bucket(Sb)
            while True:
                self._decode_packed_fn(n)(
                    self.params, jnp.zeros((n, self.cfg.d_model), dt),
                    jnp.ones((n,), bool))
                if n >= self._logit_bucket(max_logits):
                    break
                n = min(n * 2, self._logit_bucket(max_logits))
        else:
            # padded decode buckets: the runtime requests pow2_bucket(N,
            # lo=Sb) for N <= max_logits rows, so the bucket-cover invariant
            # stops exactly at pow2_bucket(max_logits, lo=Sb) — the old
            # ``while n <= max_logits * 2`` bound compiled one pow2 bucket
            # beyond anything the runtime can ever request.
            n = Sb
            while True:
                self._decode_fn(n)(self.params,
                                   jnp.zeros((n, self.cfg.d_model), dt))
                if n >= _bucket(max_logits, lo=Sb):
                    break
                n *= 2

    def submit(self, prompt: np.ndarray, gen_len: int, arrival: float = 0.0,
               rid: Optional[int] = None,
               frontend: Optional[np.ndarray] = None,
               deadline: float = math.inf) -> Request:
        """Queue a request. For modality-frontend archs ``frontend`` carries
        the request's precomputed patch/frame embeddings
        ``[frontend_len, frontend_dim]`` (the stub contract: the vision/audio
        tower runs offline); omitted, a deterministic stand-in is drawn from
        the engine rng so synthetic workloads exercise the real geometry.

        Admission control (docs/robustness.md): a request that can NEVER be
        admitted (total_len > max_seq_len, or Refresh cost > the token
        budget) is returned immediately in a terminal REJECTED state with a
        per-request ``error`` — it is never enqueued and cannot stall the
        engine. Under ``queue_cap`` the bounded-queue policy may reject this
        request or shed the oldest waiter instead; check ``req.outcome``.
        ``deadline`` is absolute trace time (inf = none): expired waiters
        are shed at plan time with Outcome.SHED_DEADLINE."""
        if self.cfg.frontend_dim:
            if frontend is None:
                frontend = self._rng.standard_normal(
                    (self.cfg.frontend_len, self.cfg.frontend_dim)).astype(
                        np.float32)
            frontend = np.asarray(frontend, np.float32)
            assert frontend.shape == (self.cfg.frontend_len,
                                      self.cfg.frontend_dim), frontend.shape
        else:
            assert frontend is None, \
                f"{self.cfg.name} is text-only but got frontend embeddings"
        req = Request(rid=rid if rid is not None else next(self._rid_counter),
                      prompt=np.asarray(prompt, np.int32), gen_len=gen_len,
                      arrival=arrival, cfg=self.serve, mask_id=self.mask_id,
                      frontend=frontend, deadline=deadline)
        self.stats.submitted += 1
        reason = admission_block_reason(self.serve, req)
        if reason is not None:
            req.state = State.REJECTED
            req.outcome = Outcome.REJECTED_OVERSIZED
            req.error = reason
            self._tally(req)
            return req
        for casualty in self.scheduler.submit(req):
            self._tally(casualty)     # bounded-queue reject/evict victims
        return req

    def _tally(self, req: Request) -> None:
        """Record a terminal outcome in the conservation counters."""
        o = req.outcome
        if o is Outcome.FINISHED:
            self.stats.finished += 1
        elif o is Outcome.REJECTED_OVERSIZED:
            self.stats.rejected_oversized += 1
        elif o is Outcome.REJECTED_QUEUE_FULL:
            self.stats.rejected_queue_full += 1
        elif o is Outcome.SHED_DEADLINE:
            self.stats.shed_deadline += 1
        elif o is Outcome.SHED_QUEUE:
            self.stats.shed_queue += 1
        else:                          # pragma: no cover - defensive
            raise AssertionError(f"tally of non-terminal request {req.rid}")

    def run(self, time_scale: float = 1.0, max_iters: int = 100_000,
            quiet: bool = True) -> EngineStats:
        """Serve until every submitted request reaches a terminal state
        (FINISHED, or SHED / REJECTED by the admission-control layer).

        wall clock: ``time_scale`` maps trace seconds to wall seconds.
        modeled clock: arrivals/latencies in virtual device seconds.

        Overload is NOT an error (docs/robustness.md): never-admittable
        requests are rejected with a structured per-request outcome at
        submit/plan time, deadline-expired waiters are shed, bounded queues
        apply backpressure, and starvation triggers preempt-and-requeue —
        the engine degrades instead of dying. The ``RuntimeError`` below is
        reserved for a TRUE invariant violation: a zero-progress iteration
        with admittable work resident and no future arrival, deadline, or
        pending injected fault that could unblock it (admission and
        deferral depend only on budget/slot state, which time alone cannot
        change). The old silent ``break`` here exited with unfinished
        requests still resident and recorded bogus throughput/latency
        stats for them.

        Pipelined loop (``ServeConfig.pipeline``, docs/engine.md): each lap
        (1) builds iteration i+1's plan + packed layout — pure host work
        that overlaps iteration i's dispatched stages still executing
        asynchronously on device, (2) performs the ONE deferred host sync
        of iteration i (its committed token values must land before i+1's
        stage buffers read ``r.tokens``), then (3) fills and dispatches
        i+1, leaving its sync pending for the next lap. The control plane
        (masked counts, block completion, FINISHED, the modeled clock)
        advanced at dispatch time and is value-independent, so the order
        of scheduler/stats/vtime mutations is exactly the synchronous
        loop's — bit-identity is by construction, not by luck. With
        ``pipeline=False`` each lap syncs immediately (the oracle)."""
        start = time.perf_counter()
        pending: Optional[_Pending] = None
        it = 0
        while self.scheduler.has_work and it < max_iters:
            if self.clock == "modeled":
                now = self.vtime
            else:
                now = (time.perf_counter() - start) / time_scale
            prep = self._begin_iteration(now)
            if pending is not None:
                # the plan above was built while the previous dispatch was
                # still in flight — the overlap the pipeline buys
                self.stats.overlapped_host_s += prep.plan_s
                self.stats.dispatched_ahead += 1
                self._sync_iteration(pending)
                pending = None
            if prep.has_exec:
                nxt = self._dispatch_iteration(prep)
                if self.serve.pipeline:
                    pending = nxt
                else:
                    self._sync_iteration(nxt)
                progressed = True
            else:
                progressed = prep.lifecycle
            if not progressed:
                # time CAN unblock two things: a future arrival (admission)
                # and a future deadline (shedding a waiter that will never
                # fit alongside the current residents)
                events = [r.arrival for r in self.scheduler.waiting
                          if r.arrival > now]
                events += [r.deadline for r in self.scheduler.waiting
                           if now < r.deadline < math.inf]
                nxt = min(events, default=None)
                if nxt is None and self._fault_blocked:
                    # injected alloc faults / mem-pressure steals suppress
                    # admission transiently; the schedule is finite and
                    # advances per iteration, so spin — never a stall
                    it += 1
                    continue
                if nxt is None:
                    n_run = len(self.scheduler.running)
                    n_wait = len(self.scheduler.waiting)
                    raise RuntimeError(
                        f"engine stalled with work left at t={now:.3f}: "
                        f"{n_run} running / {n_wait} waiting requests and "
                        f"an empty iteration plan that no future arrival, "
                        f"deadline, or fault schedule can unblock — an "
                        f"engine/scheduler invariant violation (oversized, "
                        f"expired, and overload traffic is rejected or "
                        f"shed with structured outcomes before this "
                        f"point). Serve limits: max_num_batched_tokens="
                        f"{self.serve.max_num_batched_tokens}, block_size="
                        f"{self.serve.block_size}, max_slots="
                        f"{self.serve.max_slots}, refresh cap="
                        f"{self.serve.refresh_slots}.")
                if self.clock == "modeled":
                    self.vtime = max(self.vtime, nxt)   # jump to next event
                else:
                    wait = nxt * time_scale - (time.perf_counter() - start)
                    if wait > 0:
                        time.sleep(min(wait, 0.05))
            it += 1
        if pending is not None:
            # drain the last in-flight iteration OUTSIDE the loop: a drain
            # lap would advance the iteration counter (and with it the
            # fault schedule) past the synchronous oracle
            self._sync_iteration(pending)
            pending = None
        self.stats.wall_time = (self.vtime if self.clock == "modeled"
                                else time.perf_counter() - start)
        self.stats.iterations = it
        self.stats.compile_counts = dict(self._compile_counter)
        if self.pool.ledger is not None:
            self.stats.shared_hits = self.pool.ledger.hits
            self.stats.shared_cow_promotes = self.pool.ledger.cow_promotes
            self.stats.phys_slots_peak = self.pool.phys_peak
        return self.stats

    # -- modeled-clock cost accounting -------------------------------------
    def _charge(self, kind: str, exec_tokens: int, kv_len: int = 0,
                actual_tokens: Optional[int] = None) -> None:
        if self.clock != "modeled":
            return
        cfg = self.cfg
        # A stage is billed for real tokens only when its packed path really
        # executed (no more "pretend-packed" carve-outs): Refresh and Reuse
        # follow the engine gate — every family packs now (attention stream,
        # segment-reset SSD scan, or frontend-prefix segments for vlm/audio;
        # the padded oracle runs only when varlen_pack is off and then pays
        # the rectangle) — while the logit stage packs under varlen_pack for
        # every family (the output head is family-agnostic, so the engine
        # always buckets the hidden stream on tokens there).
        if kind == "decode":
            varlen = self.serve.varlen_pack
        else:
            varlen = self.serve.varlen_pack and self._use_packed
        tokens = (actual_tokens if varlen
                  and actual_tokens is not None else exec_tokens)
        flops = 2.0 * self._n_params * tokens
        if cfg.has_attention and kv_len:
            dh = cfg.resolved_head_dim
            flops += 4.0 * tokens * kv_len * cfg.n_heads * dh \
                * cfg.n_layers
        if kind == "decode":
            # the fused Pallas argmax tile-skips all-pad rows (the validity
            # mask threaded into the kernel), so it pays real rows; the
            # chunked/monolithic jnp matmul computes every bucketed row of
            # its [N, V] chunk and is billed for the rectangle — the decode
            # half of the modeled-clock gap the kernels close
            rows = tokens if self.serve.logit_mode == "fused" \
                else exec_tokens
            flops = 2.0 * cfg.d_model * cfg.vocab_size * rows
        # the model (TP) axis splits real work by its actually-sharded
        # fraction (_tp_work_split: 1.0 when nothing divides); the data axis
        # multiplies in its replica streams only when the slot pool really
        # shards over it (_dp_work_split — 1.0 on a data axis of 1, so a
        # replicating mesh can never fake a speedup)
        self.vtime += self.device.call_cost(
            flops, self._tp_work_split * self._dp_work_split)

    # ------------------------------------------------------------------
    # one engine iteration
    # ------------------------------------------------------------------
    def step(self, now: float) -> bool:
        """One engine iteration, fully synchronous: plan → dispatch → sync.
        Returns True when the iteration made progress — executed work OR a
        lifecycle event (shed / rejected / preempted request), which also
        changes engine state. :meth:`run` composes the same three phases
        with the sync deferred one iteration (dispatch-ahead); direct
        callers get the oracle ordering."""
        prep = self._begin_iteration(now)
        if not prep.has_exec:
            return prep.lifecycle
        self._sync_iteration(self._dispatch_iteration(prep))
        return True

    def _begin_iteration(self, now: float) -> _Prepared:
        """Plan one iteration: fault-schedule tick, scheduler plan, packed
        layout. Pure host work — no device dispatch, no host sync — so the
        pipelined loop runs it while the previous iteration's stages are
        still executing on device. Everything here depends only on request
        lengths/phases/arrivals (never token values), which is why it can
        legally run before the previous iteration's tokens are synced."""
        t0 = time.perf_counter()
        self._iter += 1
        if self.faults is not None:
            self.faults.begin_iteration(self._iter)
            d = self.faults.take_slow_delay()
            if d:
                self.stats.slow_fault_s += d
                if self.clock == "modeled":
                    self.vtime += d
                else:
                    time.sleep(min(d, 0.05))
        plan = self.scheduler.plan(now)
        for r in plan.rejected + plan.shed:
            self._tally(r)
        self.stats.preemptions += len(plan.preempted)
        self.stats.recomputed_tokens += plan.recomputed_tokens
        if plan.alloc_faults:
            self.stats.alloc_fault_iters += 1
        # a fault-suppressed iteration must not be mistaken for a stall:
        # run() spins through it (the schedule is finite) instead of raising
        self._fault_blocked = plan.alloc_faults > 0 or (
            self.faults is not None and bool(self.scheduler.waiting)
            and self.faults.blocking())
        lifecycle = bool(plan.rejected or plan.shed or plan.preempted)
        layout = None
        if plan.has_exec:
            self.stats.deferred_steps += len(plan.deferred)
            self.stats.peak_query_tokens = max(self.stats.peak_query_tokens,
                                               plan.query_tokens)
            # whole-iteration packed layout (drives the packed pipeline)
            if self._use_packed:
                layout = plan.packed_layout(self.serve.refresh_slots)
        plan_s = time.perf_counter() - t0
        self.stats.host_plan_s += plan_s
        return _Prepared(now, plan, layout, lifecycle, plan_s)

    def _dispatch_iteration(self, prep: _Prepared) -> _Pending:
        """Fill stage buffers and launch every device dispatch for one
        planned iteration, advance the control plane, and return the
        iteration's pending sync (the decode outputs still on device).
        Modeled-clock charges happen here — the same program points the
        synchronous loop charged them at — so vtime sequencing is
        identical whether the sync is deferred or immediate."""
        t0 = time.perf_counter()
        now, plan, layout = prep.now, prep.plan, prep.layout

        hidden_rows: List[jax.Array] = []
        decoded: List[Request] = []
        cap = self.serve.refresh_slots

        # ---- Refresh: ONE fused packed dispatch / padded per-cap chunks ----
        iter_real = iter_exec = 0
        if self._use_packed:
            seg = layout.refresh_fused
            if seg is not None:
                # single fused dispatch across the refresh chunks: the whole
                # iteration's Refresh set is one ragged stream, so launch
                # overhead is paid once per iteration, not once per chunk
                chunk = list(seg.requests)
                t_real = seg.total_tokens
                bh, exec_tokens = self._run_refresh_packed(seg)
                # packed attention cost: the Pallas varlen kernel skips
                # non-intersecting segment tiles, paying Σ Sᵢ² — effective
                # kv length is the token-weighted mean segment length
                # (frontend prefix included). The jnp masked-stream fallback
                # really computes the full [T, T] rectangle and is billed
                # for it — this is the modeled-clock gap the flash kernels
                # close on the packed Refresh stream.
                if self.ctx.use_flash_kernel:
                    kv_len = sum(r.refresh_len ** 2
                                 for r in chunk) // max(t_real, 1)
                else:
                    kv_len = exec_tokens
                hidden_rows.append(bh)
                decoded.extend(chunk)
                self.stats.refresh_steps += len(chunk)
                iter_real += t_real
                iter_exec += exec_tokens
                self._charge("refresh", exec_tokens, kv_len=kv_len,
                             actual_tokens=t_real)
        else:
            for i in range(0, len(plan.refresh), cap):
                chunk = plan.refresh[i: i + cap]
                t_real = sum(r.refresh_len for r in chunk)
                bh, exec_tokens = self._run_refresh(chunk)
                hidden_rows.append(bh)
                decoded.extend(chunk)
                self.stats.refresh_steps += len(chunk)
                iter_real += t_real
                iter_exec += exec_tokens
                self._charge("refresh", exec_tokens,
                             kv_len=self.serve.max_seq_len + self._fe_len,
                             actual_tokens=t_real)

        # ---- Reuse: one ragged block stream (packed) / pow2 batch (oracle) --
        r_real = r_exec = 0
        if plan.reuse:
            r_real = len(plan.reuse) * self.serve.block_size
            if self._use_packed:
                bh, r_exec = self._run_reuse_packed(layout.reuse)
            else:
                bh, r_exec = self._run_reuse(plan.reuse)
            hidden_rows.append(bh)
            decoded.extend(plan.reuse)
            self.stats.reuse_steps += len(plan.reuse)
            self._charge("reuse", r_exec,
                         kv_len=self.ctx.retain + self.serve.block_size,
                         actual_tokens=r_real)

        # ---- budgeted logit stage (C1) over every active block ----
        n_real = n_exec = 0
        ids = conf = None
        if decoded:
            D = self.cfg.d_model
            N = n_real = len(decoded) * self.serve.block_size

            def build_h(b):
                # built INSIDE the dispatch thunk: the stage jits donate
                # their stream buffers, so the concatenated rows must die
                # with the call — and a fault-retried attempt rebuilds the
                # buffer instead of re-passing a donated one
                h = jnp.concatenate([r.reshape(-1, D)
                                     for r in hidden_rows], axis=0)
                return jnp.pad(h, ((0, b - N), (0, 0))) if b != N else h

            if self.serve.varlen_pack:
                # packed: token-bucket rounding + validity mask threaded into
                # the decode kernel — no pow2 row bucket
                b = self._logit_bucket(N)
                valid = np.zeros((b,), bool)
                valid[:N] = True
                ids, conf = self._dispatch(
                    "decode", lambda: self._decode_packed_fn(b)(
                        self.params, build_h(b), jnp.asarray(valid)))
            else:
                b = _bucket(N, lo=self.serve.block_size)
                ids, conf = self._dispatch(
                    "decode", lambda: self._decode_fn(b)(self.params,
                                                         build_h(b)))
            # C1: serial sub-batches serialize on device; monolithic runs one
            # big call (launch amortized, memory unbounded)
            if self.serve.logit_mode == "monolithic":
                self._charge("decode", b, actual_tokens=N)
                n_exec = b
            else:
                sub = self.serve.max_num_logits
                for off in range(0, b, sub):
                    act = max(0, min(sub, N - off))
                    if act == 0 and self.serve.varlen_pack:
                        break   # a packed engine never launches all-pad chunks
                    self._charge("decode", min(sub, b - off),
                                 actual_tokens=act)
                    n_exec += min(sub, b - off)
            self.stats.logit_tokens_real += n_real
            self.stats.logit_tokens_exec += n_exec

        # control-plane advance at DISPATCH time (value-independent):
        # the scheduler sees this iteration's block completions / finishes
        # before planning the next one, exactly as in the synchronous loop
        entries = self._advance_control(
            decoded, self.vtime if self.clock == "modeled" else now)

        # under iter_log_cap the log is a maxlen deque: appending evicts the
        # oldest row in O(1) — the aggregate counters above carry the
        # lifetime totals, so a long modeled-clock run doesn't grow host
        # memory one dict per iteration forever. (The deferred sync backfills
        # ``sync_s`` through the pending reference even after eviction.)
        fill_s = time.perf_counter() - t0
        self.stats.host_fill_s += fill_s
        log_row = dict(
            t=now, q_tokens=plan.query_tokens,
            n_refresh=len(plan.refresh), n_reuse=len(plan.reuse),
            n_logits=len(decoded) * self.serve.block_size,
            refresh_tokens_real=iter_real, refresh_tokens_exec=iter_exec,
            reuse_tokens_real=r_real, reuse_tokens_exec=r_exec,
            logit_tokens_real=n_real, logit_tokens_exec=n_exec,
            plan_s=prep.plan_s, fill_s=fill_s, sync_s=0.0)
        self.stats.iter_log.append(log_row)
        return _Pending(ids, conf, n_real, entries, log_row)

    def _advance_control(self, decoded: List[Request],
                         t_commit: float) -> List[_CommitEntry]:
        """Advance every scheduled request's state machine at dispatch time,
        WITHOUT the committed token values (they are still on device).

        ``diffusion.commit_count`` / ``commit_tokens`` unmask exactly
        ``min(n_commit, masked)`` positions as a function of counts alone —
        never of token values — so block completion, phase transitions,
        FINISHED, and the committed-token stat are all computable here.
        The returned entries carry what :meth:`_sync_iteration` needs to
        land the values once they arrive."""
        entries: List[_CommitEntry] = []
        for j, r in enumerate(decoded):
            steps_left = self.serve.steps_per_block - r.step_in_block
            n_commit = diffusion.commit_count(r.masked_left, steps_left)
            e = _CommitEntry(req=r, row=j, block_start=r.block_start,
                             block_idx=r.block_idx, n_commit=n_commit,
                             n_act=0, epoch=r.commit_epoch, finished=False,
                             t=t_commit)
            e.n_act = r.advance_control(n_commit, t_commit)
            self.stats.committed_tokens += e.n_act
            e.finished = r.state == State.FINISHED
            if e.finished:
                self.scheduler.finish(r)
                self._tally(r)
            entries.append(e)
        return entries

    def _sync_iteration(self, pending: _Pending) -> None:
        """The iteration's SINGLE deferred host sync: pull the decode
        outputs, land each entry's token values into its recorded block —
        unless a preemption rollback bumped the request's epoch while the
        commit was in flight, in which case the values are discarded (the
        rollback already booked them as recompute debt, and only
        mid-block Reuse residents are preemptible, so a stale epoch always
        refers to the rolled-back block itself). Streaming events fire
        here: this is the first moment the values exist host-side."""
        if pending.ids is None:
            return
        t0 = time.perf_counter()
        # one blocking transfer instead of two per-array host syncs —
        # the engine's SINGLE annotated sync point (docs/analysis.md)
        ids, conf = jax.device_get(  # lint: allow(host-sync)
            (pending.ids, pending.conf))
        sync_s = time.perf_counter() - t0
        self.stats.sync_wait_s += sync_s
        pending.log_row["sync_s"] = sync_s
        Sb = self.serve.block_size
        for e in pending.entries:
            if e.req.commit_epoch != e.epoch:
                continue          # preempted while in flight: values dropped
            rid = ids[e.row * Sb: (e.row + 1) * Sb]
            rconf = conf[e.row * Sb: (e.row + 1) * Sb]
            s = e.block_start
            newblk = diffusion.commit_tokens(e.req.tokens[s: s + Sb], rid,
                                             rconf, e.n_commit, self.mask_id)
            e.req.tokens[s: s + Sb] = newblk
            if self._stream_cb is not None:
                self.stats.streamed_events += 1
                self._stream_cb(dict(
                    rid=e.req.rid, t=e.t, block_idx=e.block_idx,
                    n_committed=e.n_act, finished=e.finished,
                    tokens=np.array(newblk)))

    # ------------------------------------------------------------------
    def _mesh_ctx(self):
        """Activate the serving mesh around a stage trace: the Pallas
        wrappers (``kernels.ops``) consult ``jax_compat.get_active_mesh()``
        at trace time to shard_map themselves over the model axis. A no-op
        (null context) without a mesh — the no-mesh path stays untouched."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return JC.use_mesh(self.mesh)

    def _dispatch(self, stage: str, thunk):
        """Run one jitted stage call under the fault-injection harness,
        inside the serving-mesh context (see :meth:`_mesh_ctx`).

        An injected (or real) :class:`FaultError` is retried with
        exponential backoff — charged to the modeled clock, slept on wall —
        up to ``ServeConfig.fault_retries`` attempts, after which it
        propagates as permanent. Without a fault plan this is a plain
        call (zero overhead on the no-faults path)."""
        if self.faults is None:
            with self._mesh_ctx():
                return thunk()
        attempt = 0
        while True:
            attempt += 1
            try:
                if self.faults.take_dispatch_fault(stage):
                    raise FaultError(
                        f"injected {stage} dispatch fault "
                        f"(iter {self._iter}, attempt {attempt})")
                with self._mesh_ctx():
                    return thunk()
            except FaultError:
                if attempt >= self.serve.fault_retries:
                    raise
                self.stats.dispatch_retries += 1
                backoff = self.device.launch_s * (2 ** (attempt - 1))
                if self.clock == "modeled":
                    self.vtime += backoff
                else:
                    time.sleep(min(backoff, 0.05))

    def _check_slots(self, reqs: List[Request]) -> None:
        """Slot-handle integrity guard before any pool write/gather: a None
        slot or a generation mismatch means a freed-and-recycled slot is
        about to be touched for a stale holder — always an engine bug (or a
        deliberate test injection), never a recoverable serving condition."""
        for r in reqs:
            if r.slot is None or r.slot_gen is None:
                raise RuntimeError(
                    f"stale slot handle: request {r.rid} scheduled with no "
                    f"slot (state={r.state})")
            gen = self.pool.generation(r.slot)
            if gen != r.slot_gen:
                raise RuntimeError(
                    f"stale slot handle: request {r.rid} holds slot "
                    f"{r.slot}@gen{r.slot_gen} but the pool is at gen "
                    f"{gen} — the slot was freed and recycled under the "
                    f"request")

    def _pool_write(self, chunk: List[Request], cache, n_pad: int) -> None:
        """Land one Refresh batch in the slot pool. With sharing enabled the
        write is content-addressed: each request's Refresh key routes
        through the pool's share ledger (dedup hit -> device write skipped,
        divergence -> COW promote), padding rows (key None) always scatter
        to scratch. Without sharing this is the plain batched scatter."""
        slots = [r.slot for r in chunk] + \
            [self.pool.scratch_slot] * n_pad
        if not self._sharing:
            self.pool.write(slots, cache)
            return
        keys = [r.refresh_key() for r in chunk] + [None] * n_pad
        self.pool.write_shared(slots, cache, keys)

    def _run_refresh(self, chunk: List[Request]) -> Tuple[jax.Array, int]:
        """Padded-oracle Refresh. For modality-frontend archs the embedded
        batch is ``[b, frontend_len + max_seq_len]`` (prefix rows first), so
        validity, block offsets, and the executed-token bill all span the
        full rectangle. Returns (block hidden, executed tokens)."""
        n = len(chunk)
        b = _bucket(n)
        S = self.serve.max_seq_len
        F = self._fe_len
        tokens = np.zeros((b, S), np.int32)
        valid = np.zeros((b, F + S), bool)
        bstart = np.zeros((b,), np.int32)
        fe = np.zeros((b, F, self.cfg.frontend_dim), np.float32) \
            if F else None
        for j, r in enumerate(chunk):
            tokens[j] = r.tokens
            valid[j, : F + r.total_len] = True
            bstart[j] = F + r.block_start
            if F:
                fe[j] = r.frontend
        self._check_slots(chunk)
        out = self._dispatch("refresh", lambda: self._refresh_fn(b)(
            self.params, jnp.asarray(tokens), jnp.asarray(valid),
            jnp.asarray(bstart), jnp.asarray(fe) if F else None))
        self._pool_write(chunk, out.cache, b - n)
        self.stats.padded_refresh_calls += 1
        self.stats.refresh_tokens_real += sum(r.refresh_len for r in chunk)
        self.stats.refresh_tokens_exec += b * (F + S)
        return out.block_hidden[:n], b * (F + S)

    def _run_refresh_packed(self, seg_layout) -> Tuple[jax.Array, int]:
        """Token-packed Refresh (§4.1): one ragged stream bucketed on total
        tokens — real compute pays for real tokens, never a
        ``[B, max_seq_len]`` padded call. The stream offsets come straight
        from the scheduler's :class:`StageSegments` (the plan-level
        cu_seqlens contract drives execution); for vlm/audio each segment
        carries its ``frontend_len`` projected prefix rows ahead of the
        text tokens, already accounted in those offsets. Returns (block
        hidden, executed tokens = the token bucket)."""
        chunk = seg_layout.requests
        cu_real = seg_layout.cu_seqlens
        n = len(chunk)
        rp = _bucket(n)
        t_real = seg_layout.total_tokens
        tp = self._token_bucket(t_real)
        F = self._fe_len
        tokens = np.zeros((tp,), np.int32)
        pos = np.zeros((tp,), np.int32)
        seg = np.full((tp,), FV.PAD_SEG, np.int32)
        valid = np.zeros((tp,), bool)
        # padding requests point at the (invalid) tail so their gathers are
        # in-bounds; their caches land in the scratch slot. (Their lens stay
        # 0, which is what keeps embed_inputs_packed from scattering frontend
        # rows over real tokens when the bucket is exactly full.)
        cu = np.full((rp,), max(0, tp - 1), np.int32)
        lens = np.zeros((rp,), np.int32)
        bstart = np.zeros((rp,), np.int32)
        fe = np.zeros((rp, F, self.cfg.frontend_dim), np.float32) \
            if F else None
        for j, r in enumerate(chunk):
            off = int(cu_real[j])
            ln = r.refresh_len            # frontend prefix + text
            assert ln == int(cu_real[j + 1]) - off, "layout/request mismatch"
            # segment = [F projected frontend rows ; total_len text tokens];
            # the prefix token ids are placeholders (embed_inputs_packed
            # overwrites those embedding rows with the projected frontend)
            tokens[off + F: off + ln] = r.tokens[: r.total_len]
            pos[off: off + ln] = np.arange(ln, dtype=np.int32)
            seg[off: off + ln] = j
            valid[off: off + ln] = True
            cu[j] = off
            lens[j] = ln
            bstart[j] = F + r.block_start
            if F:
                fe[j] = r.frontend
        self._check_slots(list(chunk))
        out = self._dispatch("refresh", lambda: self._refresh_packed_fn(
            tp, rp)(
            self.params, jnp.asarray(tokens), jnp.asarray(pos),
            jnp.asarray(seg), jnp.asarray(valid), jnp.asarray(cu),
            jnp.asarray(lens), jnp.asarray(bstart),
            jnp.asarray(fe) if F else None))
        self._pool_write(list(chunk), out.cache, rp - n)
        self.stats.packed_refresh_calls += 1
        self.stats.refresh_tokens_real += t_real
        self.stats.refresh_tokens_exec += tp
        return out.block_hidden[:n], tp

    def _run_reuse(self, reqs: List[Request]) -> Tuple[jax.Array, int]:
        """Padded-oracle Reuse: pow2 request bucket, scratch-slot pad rows.
        Returns (block hidden [n, Sb, D], executed tokens = bucket·Sb)."""
        n = len(reqs)
        b = _bucket(n)
        Sb = self.serve.block_size
        btok = np.zeros((b, Sb), np.int32)
        bpos = np.zeros((b, Sb), np.int32)
        slots = [self.pool.scratch_slot] * b
        F = self._fe_len
        for j, r in enumerate(reqs):
            btok[j] = r.block_tokens()
            bpos[j] = np.arange(F + r.block_start, F + r.block_start + Sb)
            slots[j] = r.slot
        self._check_slots(reqs)
        # gather INSIDE the thunk: the reuse jit donates the gathered cache,
        # so each dispatch attempt (fault retries included) needs its own
        h = self._dispatch("reuse", lambda: self._reuse_fn(b)(
            self.params, jnp.asarray(btok), jnp.asarray(bpos),
            self.pool.gather(slots)))
        self.stats.padded_reuse_calls += 1
        self.stats.reuse_tokens_real += n * Sb
        self.stats.reuse_tokens_exec += b * Sb
        return h[:n], b * Sb

    def _run_reuse_packed(self, seg_layout) -> Tuple[jax.Array, int]:
        """Token-packed Reuse: the iteration's active blocks run as one
        ragged ``[R·Sb]`` query stream against their gathered slot caches —
        R is rounded only to the token-bucket granularity (scratch slots
        back the padding segments), never a pow2 batch bucket. Returns
        (block hidden [n, Sb, D], executed tokens = rp·Sb)."""
        reqs = seg_layout.requests
        n = len(reqs)
        Sb = self.serve.block_size
        rp = self._reuse_bucket(n)
        tq = rp * Sb
        btok = np.zeros((tq,), np.int32)
        bpos = np.zeros((tq,), np.int32)
        slots = [self.pool.scratch_slot] * rp
        F = self._fe_len
        for j, r in enumerate(reqs):
            off = int(seg_layout.cu_seqlens[j])
            btok[off: off + Sb] = r.block_tokens()
            bpos[off: off + Sb] = np.arange(F + r.block_start,
                                            F + r.block_start + Sb)
            slots[j] = r.slot
        self._check_slots(list(reqs))
        # gather INSIDE the thunk (donated cache; see _run_reuse)
        h = self._dispatch("reuse", lambda: self._reuse_packed_fn(rp)(
            self.params, jnp.asarray(btok), jnp.asarray(bpos),
            self.pool.gather(slots)))
        self.stats.packed_reuse_calls += 1
        self.stats.reuse_tokens_real += n * Sb
        self.stats.reuse_tokens_exec += tq
        return h.reshape(rp, Sb, -1)[:n], tq
