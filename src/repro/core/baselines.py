"""Baseline serving systems (paper §6.1) expressed as ServeConfig profiles.

Every system runs through the same Engine so differences come only from the
policies the paper varies — scheduler granularity, KV selection, refresh
cadence, and logit handling:

  * **Fast-dLLM** (Dual-Cache, parallel decoding disabled): request-level
    static batching, dense block KV reuse (refresh only at block
    transitions), monolithic logits.
  * **dLLM-Cache**: request-level batching, dense cache with adaptive partial
    refresh modeled by its generation-interval cadence (7 steps), monolithic
    logits.
  * **Sparse-dLLM**: request-level batching, *uniform* (head-shared) top-k
    retention at r=0.5, monolithic logits.
  * **dLLM-Serve** (ours): phase-multiplexed scheduler, *head-centric*
    retention at r=0.5, budgeted logit stage.

Slot capacity per system comes from the offline profiler (§4.2): the same
HBM budget is split into weights + activation reservation + KV pool, so
systems that reserve a monolithic logit buffer or keep dense caches fit
fewer concurrent requests — the paper's capacity coupling, reproduced
mechanically rather than hard-coded.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig, ServeConfig
from repro.core.budgeting import plan_memory


def system_profiles(base: ServeConfig) -> Dict[str, ServeConfig]:
    r = dataclasses.replace
    return {
        "fast-dllm": r(base, scheduler="request", selection="none",
                       retention_ratio=1.0, refresh_interval=0,
                       logit_mode="monolithic"),
        "dllm-cache": r(base, scheduler="request", selection="none",
                        retention_ratio=1.0, refresh_interval=7,
                        logit_mode="monolithic"),
        "sparse-dllm": r(base, scheduler="request", selection="uniform",
                         retention_ratio=0.5, refresh_interval=8,
                         logit_mode="monolithic"),
        "dllm-serve": r(base, scheduler="phase", selection="head",
                        retention_ratio=0.5, refresh_interval=8,
                        logit_mode="chunked", varlen_pack=True),
    }


def ablation_profiles(base: ServeConfig) -> Dict[str, ServeConfig]:
    """§6.6 cumulative toggles on top of the Sparse-dLLM baseline."""
    r = dataclasses.replace
    baseline = r(base, scheduler="request", selection="uniform",
                 retention_ratio=0.5, refresh_interval=8,
                 logit_mode="monolithic")
    # custom engine: head-centric packed KV + varlen flattening (§6.6)
    engine = r(baseline, selection="head", varlen_pack=True)
    sched = r(engine, scheduler="phase")                  # + smart scheduler
    budget = r(sched, logit_mode="chunked")               # + logit budgeting
    return {"baseline": baseline, "+engine": engine,
            "+scheduler": sched, "+budgeting": budget}


def size_slots(cfg: ModelConfig, serve: ServeConfig, hbm_bytes: int,
               floor: int = 1, share_factor: float = 1.0) -> ServeConfig:
    """Clamp max_slots to what the profiler says fits the HBM budget.

    ``share_factor`` (the workload's measured prefix-sharing ratio) reaches
    the plan so its logical capacity is reported, but sizing clamps to the
    plan's PHYSICAL capacity: the pool reserves physical backing per
    logical slot (docs/memory.md), so allocating the logical count would
    overshoot the HBM budget. The logical headroom is what a paged
    overcommit pool would unlock (ROADMAP follow-up). int8 ``kv_quant``
    needs no such care — it genuinely shrinks ``slot_bytes``, so the
    physical capacity itself grows."""
    plan = plan_memory(cfg, serve, hbm_bytes, share_factor=share_factor)
    fit = plan.phys_slots or plan.max_slots
    return dataclasses.replace(
        serve, max_slots=max(floor, min(serve.max_slots, fit)))
