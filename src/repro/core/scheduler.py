"""Schedulers: the paper's Phase-Multiplexed Greedy Scheduler (§4.4) and the
request-level static baseline it is evaluated against (§3.1).

Invariant (strict, property-tested): the packed iteration never carries more
*query tokens* than ``max_num_batched_tokens``. Query tokens are the
scheduling currency because per-iteration activation workspace scales with
them, while KV sits in the pre-allocated pool and logits are bounded
separately by ``max_num_logits`` (C1).

Robustness layer (``docs/robustness.md``): ``plan()`` additionally (a)
rejects never-admittable waiters (a whole-queue sweep, so an oversized head
can no longer head-of-line block traffic behind it), (b) sheds waiters whose
deadline expired, (c) under ``queue_cap`` bounds the waiting queue at submit
time (reject-new or evict-oldest), and (d) with ``preempt_starvation_s`` set
preempts the youngest Reuse-phase resident when the head waiter starves with
no free slot — the victim rolls its active block back and requeues at the
TAIL (tail placement is what makes preemption convergent: an arrival-ordered
reinsert would put the victim back ahead of the starved head and loop).
Shed/rejected/preempted requests are reported on the plan for the engine's
stats; the scheduler never raises for overload.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.configs.base import ServeConfig
from repro.core.budgeting import admission_block_reason
from repro.core.request import Outcome, Phase, Request, State


@dataclass(frozen=True)
class StageSegments:
    """One packed sub-stream of an iteration: requests in stream order plus
    the exclusive prefix offsets of their token spans. ``cu_seqlens[j]`` is
    where request j's tokens start in the stage's flat stream;
    ``cu_seqlens[-1]`` is the stream's true (pre-bucketing) length."""
    requests: Tuple[Request, ...]
    cu_seqlens: np.ndarray          # [n + 1] int32

    @property
    def total_tokens(self) -> int:
        return int(self.cu_seqlens[-1])

    @property
    def token_counts(self) -> List[int]:
        return [int(d) for d in np.diff(self.cu_seqlens)]


@dataclass(frozen=True)
class PackedIterationLayout:
    """Whole-iteration packed layout (§4.1 flattened engine, every stage).

    The engine's single packed pipeline is driven entirely by this object:
    Refresh runs one ragged stream per ``max_refresh_per_iter`` chunk, Reuse
    runs the iteration's active blocks as one ragged ``[R·Sb]`` stream, and
    the logit stage decodes the concatenated ``logit_tokens`` hidden rows at
    token-bucket granularity. Per-stage ``cu_seqlens`` partition each stream
    exactly (property-tested: contiguous, non-overlapping, gap-free).
    Modality-frontend archs contribute their ``frontend_len`` prefix rows to
    the Refresh cu_seqlens ONLY — Reuse segments are exactly ``block_size``
    and ``logit_tokens`` counts one text block per scheduled request, so
    frontend prefixes can never leak into the Reuse or logit streams
    (property-tested)."""
    refresh_chunks: Tuple[StageSegments, ...]
    reuse: Optional[StageSegments]
    logit_tokens: int               # real hidden rows entering the C1 stage
    # The iteration's WHOLE Refresh set as one stream (ROADMAP: "single fused
    # dispatch across refresh chunks") — the plan-level cu_seqlens verbatim.
    # The engine's packed pipeline launches this ONE dispatch instead of one
    # per chunk, amortizing launch overhead across the full token budget;
    # refresh_chunks remain the per-cap tiling of the same stream (the padded
    # oracle's serial chunking, property-tested against this stream).
    refresh_fused: Optional[StageSegments] = None

    @property
    def refresh_total_tokens(self) -> int:
        return sum(c.total_tokens for c in self.refresh_chunks)

    @property
    def reuse_total_tokens(self) -> int:
        return self.reuse.total_tokens if self.reuse else 0


@dataclass
class IterationPlan:
    refresh: List[Request] = field(default_factory=list)
    reuse: List[Request] = field(default_factory=list)
    deferred: List[Request] = field(default_factory=list)
    admitted: List[Request] = field(default_factory=list)
    # robustness events this iteration (terminal requests carry Outcome)
    rejected: List[Request] = field(default_factory=list)
    shed: List[Request] = field(default_factory=list)
    preempted: List[Request] = field(default_factory=list)   # requeued, live
    alloc_faults: int = 0        # injected transient slot-alloc failures hit
    recomputed_tokens: int = 0   # commits discarded by preemption rollbacks

    @property
    def has_exec(self) -> bool:
        """True when the iteration executes device work (an empty plan is
        lifecycle-only). Everything a plan exposes — costs, layouts, this
        flag — is a function of request LENGTHS, phases, and config, never
        of token values: ``plan()`` reads arrivals, deadlines, phase
        counters, and ``refresh_len``/``query_tokens`` geometry only.
        That value-independence is the contract the pipelined engine
        relies on to build iteration i+1's plan before iteration i's
        committed tokens have been synced from device (docs/engine.md)."""
        return bool(self.refresh or self.reuse)

    @property
    def query_tokens(self) -> int:
        return sum(r.query_tokens for r in self.refresh + self.reuse)

    @property
    def n_logit_tokens(self) -> int:
        # every scheduled request decodes its active block this step
        return sum(r.cfg.block_size for r in self.refresh + self.reuse)

    # -- token-packed (varlen) Refresh layout (§4.1 flattened engine) -------
    @property
    def refresh_token_counts(self) -> List[int]:
        """True per-request row counts of the Refresh set. For vlm/audio
        archs this INCLUDES the ``frontend_len`` projected prefix rows —
        each request's segment in the flat Refresh stream is
        ``[frontend prefix ; text]`` and the cu_seqlens account both. Reuse
        and logit cu_seqlens stay text-only (the active block never carries
        a prefix)."""
        return [r.refresh_len for r in self.refresh]

    @property
    def refresh_total_tokens(self) -> int:
        return sum(self.refresh_token_counts)

    def refresh_cu_seqlens(self) -> np.ndarray:
        """[n_refresh + 1] int32 exclusive prefix offsets of the plan-level
        packed Refresh stream. This is no longer a descriptive contract:
        :meth:`packed_layout` slices it into per-chunk offsets and the
        engine's packed pipeline executes exactly those offsets."""
        return np.concatenate(
            [[0], np.cumsum(self.refresh_token_counts)]).astype(np.int32)

    def packed_layout(self, max_refresh_per_iter: int = 0
                      ) -> PackedIterationLayout:
        """Build the whole-iteration packed layout the engine executes.

        Refresh is sliced into ``max_refresh_per_iter`` chunks (0 = one
        chunk); each chunk's cu_seqlens are the plan-level offsets rebased to
        the chunk, so the per-chunk streams tile the plan stream exactly.
        Reuse is one stream of ``block_size`` segments. ``logit_tokens`` is
        the real row count of the concatenated block-hidden stream."""
        cap = max(1, max_refresh_per_iter) if max_refresh_per_iter \
            else max(1, len(self.refresh))
        cu = self.refresh_cu_seqlens()
        chunks = []
        for i in range(0, len(self.refresh), cap):
            reqs = tuple(self.refresh[i: i + cap])
            chunks.append(StageSegments(
                reqs, (cu[i: i + len(reqs) + 1] - cu[i]).astype(np.int32)))
        reuse = None
        if self.reuse:
            Sb = self.reuse[0].cfg.block_size
            reuse = StageSegments(
                tuple(self.reuse),
                (np.arange(len(self.reuse) + 1) * Sb).astype(np.int32))
        fused = StageSegments(tuple(self.refresh), cu) if self.refresh \
            else None
        return PackedIterationLayout(tuple(chunks), reuse,
                                     self.n_logit_tokens, fused)


class PhaseMultiplexedScheduler:
    """Step-granular token packing with greedy FCFS admission.

    Each iteration: (1) running requests contribute their phase-dependent
    query cost (Refresh: L_total, Reuse: L_block) in FCFS order up to the
    budget — Refresh steps that don't fit are *deferred*, not dropped;
    (2) waiting requests are admitted into free slots while their initial
    Refresh cost still fits. Admission happens exactly when running requests
    drop into Reuse and release budget — the paper's phase multiplexing.
    """

    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg
        self.waiting: List[Request] = []
        self.running: List[Request] = []
        self._free_slots = list(range(cfg.max_slots))[::-1]
        # wired by the engine: slot-lifecycle ledger + fault schedule. Both
        # optional — the scheduler runs standalone in unit tests without them.
        self.pool = None            # KVPool (take/free generation ledger)
        self.faults = None          # FaultPlan (alloc faults, mem steals)

    # -- queue ops ----------------------------------------------------------
    def submit(self, req: Request) -> List[Request]:
        """Enqueue ``req``; returns the requests the bounded-queue policy
        dropped (terminal, Outcome set) — empty with ``queue_cap=0``."""
        cap = self.cfg.queue_cap
        if cap and len(self.waiting) >= cap:
            if self.cfg.queue_policy == "evict":
                victim = self.waiting.pop(0)
                self._terminal(victim, State.SHED, Outcome.SHED_QUEUE,
                               f"evicted: queue_cap={cap} reached")
                self.waiting.append(req)
                return [victim]
            self._terminal(req, State.REJECTED, Outcome.REJECTED_QUEUE_FULL,
                           f"rejected: queue_cap={cap} reached")
            return [req]
        self.waiting.append(req)
        return []

    def finish(self, req: Request) -> None:
        self.running.remove(req)
        self._release_slot(req)

    def _release_slot(self, req: Request) -> None:
        if req.slot is not None:
            if self.pool is not None:
                self.pool.free([req.slot])
            self._free_slots.append(req.slot)
        req.slot = None
        req.slot_gen = None

    def _claim_slot(self, req: Request) -> None:
        slot = self._free_slots.pop()
        req.slot = slot
        req.slot_gen = self.pool.take(slot) if self.pool is not None else 0

    @staticmethod
    def _terminal(req: Request, state: State, outcome: Outcome,
                  error: Optional[str] = None) -> None:
        req.state = state
        req.outcome = outcome
        req.error = error

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # -- robustness sweeps ---------------------------------------------------
    def _shed_and_reject(self, now: float, plan: IterationPlan) -> None:
        """Whole-queue sweep (NOT just the head — a never-admittable or
        expired head must not head-of-line block live traffic behind it):
        reject requests that can never be admitted, shed expired ones."""
        keep = []
        for r in self.waiting:
            reason = admission_block_reason(self.cfg, r)
            if reason is not None:
                self._terminal(r, State.REJECTED,
                               Outcome.REJECTED_OVERSIZED, reason)
                plan.rejected.append(r)
            elif r.deadline <= now:
                self._terminal(r, State.SHED, Outcome.SHED_DEADLINE)
                plan.shed.append(r)
            else:
                keep.append(r)
        self.waiting = keep

    def _stolen(self) -> int:
        return self.faults.stolen_slots() if self.faults is not None else 0

    def _maybe_preempt(self, now: float, plan: IterationPlan) -> None:
        """Preempt-to-reclaim: when the head waiter has starved past
        ``preempt_starvation_s`` with no usable free slot, the youngest
        Reuse-phase resident rolls its active block back, frees its slot,
        and requeues at the TAIL of the waiting queue (tail placement bounds
        thrash — reinserting in arrival order would put the victim back
        ahead of the very head it was preempted for). Per-request
        ``max_preemptions`` caps repeat victims."""
        thr = self.cfg.preempt_starvation_s
        if not thr or not self.waiting:
            return
        head = self.waiting[0]
        if head.arrival > now or now - head.arrival < thr:
            return
        if len(self._free_slots) - self._stolen() > 0:
            return                      # a slot is free; admission will run
        eligible = [v for v in reversed(self.running)
                    if v.phase is Phase.REUSE          # Refresh-phase work is
                                                       # about to pay its
                                                       # recompute anyway
                    and v.n_preempted < self.cfg.max_preemptions]
        # prefer victims whose slot does not OWN shared content: evicting a
        # shared owner forces a promote copy before the slot can be reused
        # (KVPool.free) and re-bills the content to a referrer. With sharing
        # off shared_refs is 0 for every slot, so this two-pass pick reduces
        # to the original youngest-first order bit-for-bit.
        def owns_shared(v):
            return self.pool is not None and self.pool.shared_refs(v.slot) > 1
        for victim in ([v for v in eligible if not owns_shared(v)]
                       or eligible)[:1]:
            self.running.remove(victim)
            self._release_slot(victim)
            plan.recomputed_tokens += victim.rollback_block()
            victim.n_preempted += 1
            victim.state = State.WAITING
            self.waiting.append(victim)
            plan.preempted.append(victim)
            return

    # -- planning -------------------------------------------------------------
    def plan(self, now: float) -> IterationPlan:
        budget = self.cfg.max_num_batched_tokens
        plan = IterationPlan()
        # normalized cap: 0 = unlimited (ServeConfig.refresh_slots). Reading
        # the raw field here livelocked ``max_refresh_per_iter=0``: every
        # Refresh compared ``len < 0`` false, was deferred forever, and
        # blocked admission with it.
        refresh_slots = self.cfg.refresh_slots

        # 0) robustness sweeps: structured rejection/shedding, then
        # starvation-triggered preemption (frees a slot admission can use
        # in this same iteration)
        self._shed_and_reject(now, plan)
        self._maybe_preempt(now, plan)

        # 1) running requests, FCFS
        for r in self.running:
            cost = r.query_tokens
            if r.phase == Phase.REFRESH:
                if cost <= budget and len(plan.refresh) < refresh_slots:
                    plan.refresh.append(r)
                    budget -= cost
                else:
                    plan.deferred.append(r)
            else:
                if cost <= budget:
                    plan.reuse.append(r)
                    budget -= cost
                else:
                    plan.deferred.append(r)

        # 2) greedy FCFS admission into released headroom. The sweep in (0)
        # already removed never-admittable requests, so a ``break`` here is
        # always a TRANSIENT condition (future arrival, budget consumed this
        # iteration, mem-pressure steal, injected alloc fault) — head-of-line
        # waiting, never head-of-line deadlock.
        stolen = self._stolen()
        while (self.waiting and len(self._free_slots) - stolen > 0
               and len(plan.refresh) < refresh_slots):
            cand = self.waiting[0]
            if cand.arrival > now:
                break
            cost = cand.refresh_len  # first step is a Refresh (prefix + text)
            if cost > budget:
                break
            if self.faults is not None and self.faults.take_alloc_fault():
                plan.alloc_faults += 1     # transient: admit next iteration
                break
            self.waiting.pop(0)
            self._claim_slot(cand)
            cand.state = State.RUNNING
            cand.t_admitted = now
            self.running.append(cand)
            plan.refresh.append(cand)
            plan.admitted.append(cand)
            budget -= cost

        return plan


class RequestLevelScheduler(PhaseMultiplexedScheduler):
    """§3.1 baseline: STATIC request-granular batching (paper Table 1).

    Fast-dLLM / dLLM-Cache / Sparse-dLLM batch statically: a batch is formed,
    runs to completion, and only then is the next batch admitted. Every
    resident request is provisioned for its worst case (Refresh cost =
    L_total) for its entire lifetime — the "granularity mismatch" +
    head-of-line blocking the paper attacks.
    """

    def plan(self, now: float) -> IterationPlan:
        plan = IterationPlan()
        budget = self.cfg.max_num_batched_tokens

        # same structured rejection/shedding sweep as the phase scheduler —
        # static batching is even MORE exposed to head-of-line deadlock (an
        # oversized head would block every future batch). No preemption:
        # the baseline's batches run to completion by definition.
        self._shed_and_reject(now, plan)

        # conservative: every running request is charged its worst case
        for r in self.running:
            budget -= r.refresh_len
            (plan.refresh if r.phase == Phase.REFRESH else plan.reuse).append(r)

        # static batching: admit only when the previous batch fully drained
        # (the engine executes oversized refresh sets in serial chunks)
        drained = not self.running
        stolen = self._stolen()
        while drained and self.waiting and len(self._free_slots) - stolen > 0:
            cand = self.waiting[0]
            if cand.arrival > now or cand.refresh_len > budget:
                break
            if self.faults is not None and self.faults.take_alloc_fault():
                plan.alloc_faults += 1
                break
            self.waiting.pop(0)
            self._claim_slot(cand)
            cand.state = State.RUNNING
            cand.t_admitted = now
            self.running.append(cand)
            plan.refresh.append(cand)
            plan.admitted.append(cand)
            budget -= cand.refresh_len
        return plan


def make_scheduler(cfg: ServeConfig):
    if cfg.scheduler == "phase":
        return PhaseMultiplexedScheduler(cfg)
    if cfg.scheduler == "request":
        return RequestLevelScheduler(cfg)
    raise ValueError(cfg.scheduler)
