"""Schedulers: the paper's Phase-Multiplexed Greedy Scheduler (§4.4) and the
request-level static baseline it is evaluated against (§3.1).

Invariant (strict, property-tested): the packed iteration never carries more
*query tokens* than ``max_num_batched_tokens``. Query tokens are the
scheduling currency because per-iteration activation workspace scales with
them, while KV sits in the pre-allocated pool and logits are bounded
separately by ``max_num_logits`` (C1).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.configs.base import ServeConfig
from repro.core.request import Phase, Request, State


@dataclass(frozen=True)
class StageSegments:
    """One packed sub-stream of an iteration: requests in stream order plus
    the exclusive prefix offsets of their token spans. ``cu_seqlens[j]`` is
    where request j's tokens start in the stage's flat stream;
    ``cu_seqlens[-1]`` is the stream's true (pre-bucketing) length."""
    requests: Tuple[Request, ...]
    cu_seqlens: np.ndarray          # [n + 1] int32

    @property
    def total_tokens(self) -> int:
        return int(self.cu_seqlens[-1])

    @property
    def token_counts(self) -> List[int]:
        return [int(d) for d in np.diff(self.cu_seqlens)]


@dataclass(frozen=True)
class PackedIterationLayout:
    """Whole-iteration packed layout (§4.1 flattened engine, every stage).

    The engine's single packed pipeline is driven entirely by this object:
    Refresh runs one ragged stream per ``max_refresh_per_iter`` chunk, Reuse
    runs the iteration's active blocks as one ragged ``[R·Sb]`` stream, and
    the logit stage decodes the concatenated ``logit_tokens`` hidden rows at
    token-bucket granularity. Per-stage ``cu_seqlens`` partition each stream
    exactly (property-tested: contiguous, non-overlapping, gap-free).
    Modality-frontend archs contribute their ``frontend_len`` prefix rows to
    the Refresh cu_seqlens ONLY — Reuse segments are exactly ``block_size``
    and ``logit_tokens`` counts one text block per scheduled request, so
    frontend prefixes can never leak into the Reuse or logit streams
    (property-tested)."""
    refresh_chunks: Tuple[StageSegments, ...]
    reuse: Optional[StageSegments]
    logit_tokens: int               # real hidden rows entering the C1 stage
    # The iteration's WHOLE Refresh set as one stream (ROADMAP: "single fused
    # dispatch across refresh chunks") — the plan-level cu_seqlens verbatim.
    # The engine's packed pipeline launches this ONE dispatch instead of one
    # per chunk, amortizing launch overhead across the full token budget;
    # refresh_chunks remain the per-cap tiling of the same stream (the padded
    # oracle's serial chunking, property-tested against this stream).
    refresh_fused: Optional[StageSegments] = None

    @property
    def refresh_total_tokens(self) -> int:
        return sum(c.total_tokens for c in self.refresh_chunks)

    @property
    def reuse_total_tokens(self) -> int:
        return self.reuse.total_tokens if self.reuse else 0


@dataclass
class IterationPlan:
    refresh: List[Request] = field(default_factory=list)
    reuse: List[Request] = field(default_factory=list)
    deferred: List[Request] = field(default_factory=list)
    admitted: List[Request] = field(default_factory=list)

    @property
    def query_tokens(self) -> int:
        return sum(r.query_tokens for r in self.refresh + self.reuse)

    @property
    def n_logit_tokens(self) -> int:
        # every scheduled request decodes its active block this step
        return sum(r.cfg.block_size for r in self.refresh + self.reuse)

    # -- token-packed (varlen) Refresh layout (§4.1 flattened engine) -------
    @property
    def refresh_token_counts(self) -> List[int]:
        """True per-request row counts of the Refresh set. For vlm/audio
        archs this INCLUDES the ``frontend_len`` projected prefix rows —
        each request's segment in the flat Refresh stream is
        ``[frontend prefix ; text]`` and the cu_seqlens account both. Reuse
        and logit cu_seqlens stay text-only (the active block never carries
        a prefix)."""
        return [r.refresh_len for r in self.refresh]

    @property
    def refresh_total_tokens(self) -> int:
        return sum(self.refresh_token_counts)

    def refresh_cu_seqlens(self) -> np.ndarray:
        """[n_refresh + 1] int32 exclusive prefix offsets of the plan-level
        packed Refresh stream. This is no longer a descriptive contract:
        :meth:`packed_layout` slices it into per-chunk offsets and the
        engine's packed pipeline executes exactly those offsets."""
        return np.concatenate(
            [[0], np.cumsum(self.refresh_token_counts)]).astype(np.int32)

    def packed_layout(self, max_refresh_per_iter: int = 0
                      ) -> PackedIterationLayout:
        """Build the whole-iteration packed layout the engine executes.

        Refresh is sliced into ``max_refresh_per_iter`` chunks (0 = one
        chunk); each chunk's cu_seqlens are the plan-level offsets rebased to
        the chunk, so the per-chunk streams tile the plan stream exactly.
        Reuse is one stream of ``block_size`` segments. ``logit_tokens`` is
        the real row count of the concatenated block-hidden stream."""
        cap = max(1, max_refresh_per_iter) if max_refresh_per_iter \
            else max(1, len(self.refresh))
        cu = self.refresh_cu_seqlens()
        chunks = []
        for i in range(0, len(self.refresh), cap):
            reqs = tuple(self.refresh[i: i + cap])
            chunks.append(StageSegments(
                reqs, (cu[i: i + len(reqs) + 1] - cu[i]).astype(np.int32)))
        reuse = None
        if self.reuse:
            Sb = self.reuse[0].cfg.block_size
            reuse = StageSegments(
                tuple(self.reuse),
                (np.arange(len(self.reuse) + 1) * Sb).astype(np.int32))
        fused = StageSegments(tuple(self.refresh), cu) if self.refresh \
            else None
        return PackedIterationLayout(tuple(chunks), reuse,
                                     self.n_logit_tokens, fused)


class PhaseMultiplexedScheduler:
    """Step-granular token packing with greedy FCFS admission.

    Each iteration: (1) running requests contribute their phase-dependent
    query cost (Refresh: L_total, Reuse: L_block) in FCFS order up to the
    budget — Refresh steps that don't fit are *deferred*, not dropped;
    (2) waiting requests are admitted into free slots while their initial
    Refresh cost still fits. Admission happens exactly when running requests
    drop into Reuse and release budget — the paper's phase multiplexing.
    """

    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg
        self.waiting: List[Request] = []
        self.running: List[Request] = []
        self._free_slots = list(range(cfg.max_slots))[::-1]

    # -- queue ops ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def finish(self, req: Request) -> None:
        self.running.remove(req)
        self._free_slots.append(req.slot)
        req.slot = None

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # -- planning -------------------------------------------------------------
    def plan(self, now: float) -> IterationPlan:
        budget = self.cfg.max_num_batched_tokens
        plan = IterationPlan()
        # normalized cap: 0 = unlimited (ServeConfig.refresh_slots). Reading
        # the raw field here livelocked ``max_refresh_per_iter=0``: every
        # Refresh compared ``len < 0`` false, was deferred forever, and
        # blocked admission with it.
        refresh_slots = self.cfg.refresh_slots

        # 1) running requests, FCFS
        for r in self.running:
            cost = r.query_tokens
            if r.phase == Phase.REFRESH:
                if cost <= budget and len(plan.refresh) < refresh_slots:
                    plan.refresh.append(r)
                    budget -= cost
                else:
                    plan.deferred.append(r)
            else:
                if cost <= budget:
                    plan.reuse.append(r)
                    budget -= cost
                else:
                    plan.deferred.append(r)

        # 2) greedy FCFS admission into released headroom
        while (self.waiting and self._free_slots
               and len(plan.refresh) < refresh_slots):
            cand = self.waiting[0]
            if cand.arrival > now:
                break
            cost = cand.refresh_len  # first step is a Refresh (prefix + text)
            if cost > budget:
                break
            self.waiting.pop(0)
            cand.slot = self._free_slots.pop()
            cand.state = State.RUNNING
            cand.t_admitted = now
            self.running.append(cand)
            plan.refresh.append(cand)
            plan.admitted.append(cand)
            budget -= cost

        return plan


class RequestLevelScheduler(PhaseMultiplexedScheduler):
    """§3.1 baseline: STATIC request-granular batching (paper Table 1).

    Fast-dLLM / dLLM-Cache / Sparse-dLLM batch statically: a batch is formed,
    runs to completion, and only then is the next batch admitted. Every
    resident request is provisioned for its worst case (Refresh cost =
    L_total) for its entire lifetime — the "granularity mismatch" +
    head-of-line blocking the paper attacks.
    """

    def plan(self, now: float) -> IterationPlan:
        plan = IterationPlan()
        budget = self.cfg.max_num_batched_tokens

        # conservative: every running request is charged its worst case
        for r in self.running:
            budget -= r.refresh_len
            (plan.refresh if r.phase == Phase.REFRESH else plan.reuse).append(r)

        # static batching: admit only when the previous batch fully drained
        # (the engine executes oversized refresh sets in serial chunks)
        drained = not self.running
        while drained and self.waiting and self._free_slots:
            cand = self.waiting[0]
            if cand.arrival > now or cand.refresh_len > budget:
                break
            self.waiting.pop(0)
            cand.slot = self._free_slots.pop()
            cand.state = State.RUNNING
            cand.t_admitted = now
            self.running.append(cand)
            plan.refresh.append(cand)
            plan.admitted.append(cand)
            budget -= cand.refresh_len
        return plan


def make_scheduler(cfg: ServeConfig):
    if cfg.scheduler == "phase":
        return PhaseMultiplexedScheduler(cfg)
    if cfg.scheduler == "request":
        return RequestLevelScheduler(cfg)
    raise ValueError(cfg.scheduler)
