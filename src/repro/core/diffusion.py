"""Block-wise masked-diffusion decoding logic (LLaDA-style, §2.2-2.3).

The generation region starts fully masked. Tokens are decoded block by block
(semi-autoregressive); within a block the engine runs ``steps_per_block``
denoising steps, each committing the highest-confidence predictions among the
still-masked positions (low-confidence remasking). With
``steps_per_block == block_size`` exactly one token commits per step — the
paper's "no parallel decoding" parity setting.
"""
from __future__ import annotations

import numpy as np


def mask_token_id(vocab_size: int) -> int:
    """Reserve the last vocab id as [MASK]."""
    return vocab_size - 1


def commit_count(n_masked: int, steps_remaining: int) -> int:
    """Linear unmasking schedule: finish the block by the last step."""
    if steps_remaining <= 1:
        return n_masked
    return max(1, int(np.ceil(n_masked / steps_remaining)))


def commit_tokens(
    block_tokens: np.ndarray,   # [Sb] current block (mask_id on undecided)
    ids: np.ndarray,            # [Sb] predicted ids
    conf: np.ndarray,           # [Sb] prediction confidence
    n_commit: int,
    mask_id: int,
) -> np.ndarray:
    """Commit the n highest-confidence predictions at masked positions."""
    out = block_tokens.copy()
    masked = np.where(out == mask_id)[0]
    if masked.size == 0:
        return out
    n = min(n_commit, masked.size)
    order = masked[np.argsort(-conf[masked])][:n]
    out[order] = ids[order]
    # a model may legitimately predict [MASK]; fall back to id 0 so the
    # unmasking schedule always terminates.
    out[order] = np.where(out[order] == mask_id, 0, out[order])
    return out


def build_sequence(prompt: np.ndarray, gen_len: int, max_seq_len: int,
                   mask_id: int, pad_id: int = 0) -> np.ndarray:
    """[prompt | MASK*gen_len | pad] padded to max_seq_len."""
    total = len(prompt) + gen_len
    assert total <= max_seq_len, (total, max_seq_len)
    seq = np.full(max_seq_len, pad_id, np.int32)
    seq[: len(prompt)] = prompt
    seq[len(prompt): total] = mask_id
    return seq
