"""Head-Centric Sparse KV management (paper C3) — public entry points.

The selection/packing math lives in ``repro.models.sparse_select`` (it runs
inside the layer scan); the physical slot pool in ``repro.core.kv_pool``.
This module re-exports both so the paper-facing API matches DESIGN.md.
"""
from repro.core.kv_pool import KVPool                     # noqa: F401
from repro.models.sparse_select import (                  # noqa: F401
    PackedKV, head_scores, pack, select_and_pack, select_indices)
