"""Request lifecycle + phase state machine (paper §5.2 control plane).

A request iterates over denoising steps, alternating **Refresh** and
**Reuse** phases. Phase is derived from the cache policy: the first step of
every block refreshes (block transition), and a fixed ``refresh_interval``
forces periodic refreshes inside a block (the K_int cadence of §2.3).

Lifecycle (the robustness layer, ``docs/robustness.md``)::

    WAITING --admit--> RUNNING --all blocks done--> FINISHED
       |  ^               |
       |  '---preempt-----'      (rollback_block + tail requeue; repeatable
       |                          up to ServeConfig.max_preemptions)
       +--deadline expired--> SHED       (Outcome.SHED_DEADLINE / SHED_QUEUE)
       +--never admittable--> REJECTED   (Outcome.REJECTED_* + .error)

Terminal states always carry a structured :class:`Outcome`; REJECTED
additionally carries a human-readable ``error``. Preemption is NOT terminal:
the request rolls its active block back to all-mask and re-enters the
waiting queue, so its next step is a normal Refresh and the block's
denoising trajectory replays bit-identically (the preemption oracle).
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.configs.base import ServeConfig
from repro.core import diffusion


class State(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"
    SHED = "shed"            # terminal: dropped by deadline/backpressure policy
    REJECTED = "rejected"    # terminal: never admittable (oversized/queue full)


class Outcome(enum.Enum):
    """Structured terminal outcome (EngineStats conservation law:
    ``submitted == finished + shed + rejected``)."""
    FINISHED = "finished"
    REJECTED_OVERSIZED = "rejected_oversized"      # can never fit the budget
    REJECTED_QUEUE_FULL = "rejected_queue_full"    # bounded queue, reject-new
    SHED_DEADLINE = "shed_deadline"                # deadline expired waiting
    SHED_QUEUE = "shed_queue"                      # bounded queue, evict-oldest


class Phase(enum.Enum):
    REFRESH = "refresh"
    REUSE = "reuse"


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [P] int32
    gen_len: int
    arrival: float                      # seconds (trace time)
    cfg: ServeConfig
    mask_id: int = 0
    # modality-frontend stub (vlm/audio): precomputed patch/frame embeddings
    # occupying the first ``frontend_len`` positions of the request's full
    # sequence. None for text-only archs. The frontend rows are REAL compute
    # in every Refresh — they count as query tokens and as packed-stream rows
    # (the fixed-length segment prefix of the flattened engine).
    frontend: Optional[np.ndarray] = None   # [F, frontend_dim] float32
    # absolute trace-time deadline (inf = none). Deadline-expired WAITING
    # requests are shed with Outcome.SHED_DEADLINE; residents always run to
    # completion (shedding in-flight work would waste its compute).
    deadline: float = math.inf

    state: State = State.WAITING
    # -- control-plane mirror of the active block (docs/engine.md) ---------
    # ``masked_left`` tracks how many positions of the active block are
    # still masked WITHOUT reading token values. ``diffusion.commit_tokens``
    # unmasks exactly ``min(n_commit, masked)`` positions (committed ids are
    # never the mask id — remapped), so this counter evolves deterministically
    # from lengths/config alone. It is what lets the pipelined engine advance
    # the state machine (block completion, phase transitions, FINISHED) at
    # dispatch time while the committed token VALUES are still in flight on
    # device. Kept exactly equal to ``block_masked()`` whenever no commit is
    # pending (asserted by the pipeline bit-identity suite).
    masked_left: int = 0
    # bumped by every rollback: an in-flight commit whose recorded epoch no
    # longer matches is stale (the block was preempted under it) and its
    # token values must be dropped on sync — the rollback already booked the
    # discarded commits as recompute debt.
    commit_epoch: int = 0
    slot: Optional[int] = None
    # generation of ``slot`` at allocation time (KVPool.take). A mismatch
    # against the pool's live counter means the slot was freed and recycled
    # under this request — the engine raises instead of gathering stale KV.
    slot_gen: Optional[int] = None
    tokens: Optional[np.ndarray] = None  # [max_seq_len]
    block_idx: int = 0
    step_in_block: int = 0
    steps_done: int = 0
    # robustness bookkeeping
    n_preempted: int = 0                 # times preempted (capped by config)
    recomputed_tokens: int = 0           # commits discarded by rollbacks
    outcome: Optional[Outcome] = None    # terminal outcome (None while live)
    error: Optional[str] = None          # per-request error on rejection
    # metrics
    t_admitted: float = -1.0
    t_first_commit: float = -1.0
    t_finished: float = -1.0

    def __post_init__(self):
        pad = (-self.gen_len) % self.cfg.block_size
        self.gen_len += pad
        # oversized geometry stays constructable (tokens=None) so admission
        # control can return the request with a structured REJECTED_OVERSIZED
        # outcome instead of asserting in the constructor — the owner must
        # reject it (budgeting.admission_block_reason) before scheduling it.
        if self.total_len <= self.cfg.max_seq_len:
            self.tokens = diffusion.build_sequence(
                self.prompt, self.gen_len, self.cfg.max_seq_len, self.mask_id)
        # a fresh block region is all-mask by construction
        self.masked_left = self.cfg.block_size

    # -- geometry ----------------------------------------------------------
    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.gen_len

    @property
    def frontend_len(self) -> int:
        """Modality-frontend prefix rows (0 for text-only archs)."""
        return 0 if self.frontend is None else len(self.frontend)

    @property
    def refresh_len(self) -> int:
        """Rows one Refresh materializes for this request: the frontend
        prefix (vlm/audio) plus the full text sequence. This is the
        request's segment length in the packed Refresh stream and its
        Refresh-phase scheduling cost."""
        return self.frontend_len + self.total_len

    @property
    def n_blocks(self) -> int:
        return self.gen_len // self.cfg.block_size

    @property
    def block_start(self) -> int:
        return self.prompt_len + self.block_idx * self.cfg.block_size

    # -- phase machine -------------------------------------------------------
    @property
    def phase(self) -> Phase:
        if self.step_in_block == 0:
            return Phase.REFRESH
        if self.cfg.refresh_interval and \
                self.step_in_block % self.cfg.refresh_interval == 0:
            return Phase.REFRESH
        return Phase.REUSE

    @property
    def query_tokens(self) -> int:
        """Scheduling currency (§4.4): frontend prefix + full seq in Refresh,
        block in Reuse (the active block is always text — frontend rows are
        never re-decoded, so Reuse and the logit stage cost no prefix)."""
        if self.phase == Phase.REFRESH:
            return self.refresh_len
        return self.cfg.block_size

    def refresh_key(self) -> bytes:
        """Content address of this request's next Refresh capture.

        The captured cache is a deterministic function of (tokens, geometry,
        frontend) under the engine's fixed params, so two requests with equal
        keys produce bit-identical pool rows — the dedup law KVPool's shared
        writes rely on (docs/memory.md)."""
        from repro.core.share_ledger import content_key
        return content_key(self.tokens, self.cfg.block_size, self.total_len,
                           self.block_start, self.frontend)

    def block_tokens(self) -> np.ndarray:
        s = self.block_start
        return self.tokens[s: s + self.cfg.block_size]

    def block_masked(self) -> int:
        return int((self.block_tokens() == self.mask_id).sum())

    def advance_control(self, n_commit: int, now: float) -> int:
        """Advance the state machine by one committed denoising step WITHOUT
        the committed token values (they may still be in flight on device —
        the pipelined engine calls this at dispatch time and applies the
        synced values later via the recorded ``commit_epoch``).

        ``diffusion.commit_tokens`` unmasks exactly ``min(n_commit,
        masked)`` positions and never writes the mask id, so the masked
        count, block completion, and the FINISHED transition are all
        deterministic functions of ``n_commit`` and the counters here —
        value-independence is what makes dispatch-ahead bit-identical to
        the synchronous oracle. Returns the number of newly committed
        positions (the ``committed_tokens`` stat delta)."""
        n_act = min(n_commit, self.masked_left)
        if self.t_first_commit < 0 and n_act > 0:
            self.t_first_commit = now
        self.masked_left -= n_act
        self.steps_done += 1
        self.step_in_block += 1
        done_block = self.masked_left == 0 or \
            self.step_in_block >= self.cfg.steps_per_block
        if done_block:
            self.block_idx += 1
            self.step_in_block = 0
            self.masked_left = self.cfg.block_size
            if self.block_idx >= self.n_blocks:
                self.state = State.FINISHED
                self.outcome = Outcome.FINISHED
                self.t_finished = now
        return n_act

    def advance(self, new_block_tokens: np.ndarray, now: float) -> None:
        """Apply a committed denoising step and advance the state machine
        (the synchronous spelling: token values and control advance
        together — direct callers and the oracle tests use this)."""
        prev_masked = self.masked_left
        s = self.block_start
        self.tokens[s: s + self.cfg.block_size] = new_block_tokens
        n_left = int((new_block_tokens == self.mask_id).sum())
        self.advance_control(prev_masked - n_left, now)

    def rollback_block(self) -> int:
        """Preemption rollback: discard the active block's partial progress.

        The block region returns to all-mask and the step counter to 0, so
        on re-admission the phase machine's first step is a normal Refresh
        (step 0 of a block always refreshes) and the block's denoising
        trajectory — a deterministic function of the unchanged preceding
        context — replays bit-identically to the unpreempted run. Returns
        the number of discarded commits (recompute debt).

        The count comes from the CONTROL counter, not the token array: under
        the pipelined loop the latest commit's values may still be in
        flight, but ``masked_left`` already accounts for them, so the debt
        matches the synchronous oracle exactly. Bumping ``commit_epoch``
        makes the engine drop those in-flight values on sync instead of
        writing into the rolled-back block."""
        n = self.cfg.block_size - self.masked_left
        self.block_tokens()[:] = self.mask_id
        self.step_in_block = 0
        self.masked_left = self.cfg.block_size
        self.commit_epoch += 1
        self.recomputed_tokens += n
        return n

    def output_tokens(self) -> np.ndarray:
        return self.tokens[self.prompt_len: self.total_len]

    @property
    def latency(self) -> float:
        return self.t_finished - self.arrival

    @property
    def met_deadline(self) -> bool:
        """Finished and finished in time (goodput numerator)."""
        return self.state == State.FINISHED and self.t_finished <= self.deadline
