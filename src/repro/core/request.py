"""Request lifecycle + phase state machine (paper §5.2 control plane).

A request iterates over denoising steps, alternating **Refresh** and
**Reuse** phases. Phase is derived from the cache policy: the first step of
every block refreshes (block transition), and a fixed ``refresh_interval``
forces periodic refreshes inside a block (the K_int cadence of §2.3).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.configs.base import ServeConfig
from repro.core import diffusion


class State(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"


class Phase(enum.Enum):
    REFRESH = "refresh"
    REUSE = "reuse"


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [P] int32
    gen_len: int
    arrival: float                      # seconds (trace time)
    cfg: ServeConfig
    mask_id: int = 0
    # modality-frontend stub (vlm/audio): precomputed patch/frame embeddings
    # occupying the first ``frontend_len`` positions of the request's full
    # sequence. None for text-only archs. The frontend rows are REAL compute
    # in every Refresh — they count as query tokens and as packed-stream rows
    # (the fixed-length segment prefix of the flattened engine).
    frontend: Optional[np.ndarray] = None   # [F, frontend_dim] float32

    state: State = State.WAITING
    slot: Optional[int] = None
    tokens: Optional[np.ndarray] = None  # [max_seq_len]
    block_idx: int = 0
    step_in_block: int = 0
    steps_done: int = 0
    # metrics
    t_admitted: float = -1.0
    t_first_commit: float = -1.0
    t_finished: float = -1.0

    def __post_init__(self):
        pad = (-self.gen_len) % self.cfg.block_size
        self.gen_len += pad
        self.tokens = diffusion.build_sequence(
            self.prompt, self.gen_len, self.cfg.max_seq_len, self.mask_id)

    # -- geometry ----------------------------------------------------------
    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.gen_len

    @property
    def frontend_len(self) -> int:
        """Modality-frontend prefix rows (0 for text-only archs)."""
        return 0 if self.frontend is None else len(self.frontend)

    @property
    def refresh_len(self) -> int:
        """Rows one Refresh materializes for this request: the frontend
        prefix (vlm/audio) plus the full text sequence. This is the
        request's segment length in the packed Refresh stream and its
        Refresh-phase scheduling cost."""
        return self.frontend_len + self.total_len

    @property
    def n_blocks(self) -> int:
        return self.gen_len // self.cfg.block_size

    @property
    def block_start(self) -> int:
        return self.prompt_len + self.block_idx * self.cfg.block_size

    # -- phase machine -------------------------------------------------------
    @property
    def phase(self) -> Phase:
        if self.step_in_block == 0:
            return Phase.REFRESH
        if self.cfg.refresh_interval and \
                self.step_in_block % self.cfg.refresh_interval == 0:
            return Phase.REFRESH
        return Phase.REUSE

    @property
    def query_tokens(self) -> int:
        """Scheduling currency (§4.4): frontend prefix + full seq in Refresh,
        block in Reuse (the active block is always text — frontend rows are
        never re-decoded, so Reuse and the logit stage cost no prefix)."""
        if self.phase == Phase.REFRESH:
            return self.refresh_len
        return self.cfg.block_size

    def block_tokens(self) -> np.ndarray:
        s = self.block_start
        return self.tokens[s: s + self.cfg.block_size]

    def block_masked(self) -> int:
        return int((self.block_tokens() == self.mask_id).sum())

    def advance(self, new_block_tokens: np.ndarray, now: float) -> None:
        """Apply a committed denoising step and advance the state machine."""
        s = self.block_start
        if self.t_first_commit < 0 and \
                (new_block_tokens != self.mask_id).any():
            self.t_first_commit = now
        self.tokens[s: s + self.cfg.block_size] = new_block_tokens
        self.steps_done += 1
        self.step_in_block += 1
        done_block = (new_block_tokens != self.mask_id).all() or \
            self.step_in_block >= self.cfg.steps_per_block
        if done_block:
            self.block_idx += 1
            self.step_in_block = 0
            if self.block_idx >= self.n_blocks:
                self.state = State.FINISHED
                self.t_finished = now

    def output_tokens(self) -> np.ndarray:
        return self.tokens[self.prompt_len: self.total_len]

    @property
    def latency(self) -> float:
        return self.t_finished - self.arrival
