"""Logit-Aware Activation Budgeting + Offline Memory Profiler (paper §4.2-4.3).

The profiler maps the memory envelope under worst-case serving pressure and
returns a :class:`MemoryPlan`: how much HBM is reserved for transient
activations (dominated by the logit stage) and how many KV slots fit in the
remainder. Because the logit reservation depends on ``logit_mode``, the plan
mechanically reproduces the paper's capacity coupling: decomposing the logit
tensor shrinks the activation reservation and converts the reclaimed bytes
into additional concurrent requests ("KV Cache Maximization").

Two profiling modes:
  * analytic  — closed-form worst-case byte accounting (used for capacity
    planning of the big dry-run configs; §3.2 arithmetic).
  * measured  — lower + compile the actual step functions and read
    ``memory_analysis().temp_size_in_bytes`` (exact under XLA's static
    planner; used by the logit-budget benchmark).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ServeConfig


def dtype_bytes(dtype: str) -> int:
    return {"float32": 4, "bfloat16": 2, "float16": 2}[dtype]


# ---------------------------------------------------------------------------
# analytic accounting
# ---------------------------------------------------------------------------

def logit_exec_tokens(serve: ServeConfig, n_logit_tokens: int) -> int:
    """Rows the engine's decode dispatch actually materializes for ``n``
    real hidden rows: token-bucket rounding under the packed engine (exact
    below one bucket — rows arrive in whole blocks), pow2 rounding on the
    padded oracle. The logit stage packs for *every* family under
    ``varlen_pack`` (the output head is family-agnostic)."""
    n = max(1, n_logit_tokens)
    if serve.varlen_pack:
        return token_bucket_round(n, serve.token_bucket)
    return pow2_bucket(n, lo=serve.block_size)


def logit_activation_bytes(cfg: ModelConfig, serve: ServeConfig,
                           n_logit_tokens: int) -> int:
    """Peak bytes of the output-projection stage under each C1 mode, billed
    by *executed* rows (the engine's bucketing policy, not the real count)."""
    n_exec = logit_exec_tokens(serve, n_logit_tokens)
    if serve.logit_mode == "monolithic":
        # the paper's §3.2 boom: the full [N, V] tensor (f32 after softcap)
        return n_exec * cfg.vocab_size * 4
    if serve.logit_mode == "chunked":
        return min(n_exec, serve.max_num_logits) * cfg.vocab_size * 4
    # fused: the Pallas online kernel holds one [T_tile, V_tile] f32 block
    return 256 * serve.vocab_tile * 4


def kv_slot_bytes(cfg: ModelConfig, serve: ServeConfig) -> int:
    """Static per-request KV region (§4.5): r·L tokens, head-major dense."""
    b = dtype_bytes(serve.dtype)
    R = serve.retained_len
    if cfg.family == "ssm":
        st = cfg.n_layers * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
        cv = cfg.n_layers * (cfg.ssm_conv_kernel - 1) * (
            cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state) * b
        return st + cv
    dh = cfg.resolved_head_dim
    n_attn = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(cfg.shared_attn_interval, 1)
        st = cfg.n_layers * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
    else:
        st = 0
    kv = n_attn * cfg.n_kv_heads * R * dh * 2 * b
    meta = n_attn * cfg.n_kv_heads * R * 5  # pos(i32) + valid(bool)
    return kv + meta + st


def can_pack_tokens(cfg: ModelConfig) -> bool:
    """True when the engine's token-packed Refresh/Reuse paths apply to
    ``cfg`` — which is now EVERY family: attention archs run the
    segment-masked varlen attention stream, SSM/hybrid archs run the
    segment-reset varlen SSD scan (``models/ssm.varlen_ssd_scan`` / the
    Pallas ``kernels/ssm_scan`` kernel), and modality-frontend archs
    (vlm/audio) pack their ``frontend_len`` projected rows as a
    fixed-length prefix of each request's segment in the same flat stream.
    No family falls back to the padded oracle on the hot path, so every
    family is provisioned (and billed) by packed tokens under
    ``varlen_pack=True``. Kept as a function (single source of truth for
    the engine gate and the profiler's activation accounting) so a future
    family with a genuinely unpackable geometry has one place to opt out.
    """
    del cfg  # every family packs
    return True


def pow2_bucket(n: int, lo: int = 1) -> int:
    """Smallest power-of-two multiple of ``lo`` that is ≥ n (the static-shape
    bucketing policy shared by the engine's jit caches and this profiler)."""
    b = lo
    while b < n:
        b *= 2
    return b


def token_bucket_round(n: int, bucket: int) -> int:
    """Packed-stream rounding, the single source of truth for the engine's
    Reuse/logit buckets and this profiler's exec-token accounting: exact
    below one bucket, ceil to bucket multiples above, and never beyond the
    pow2 oracle bucket — the invariant the CI waste gate asserts (the cap
    only binds for non-pow2 ``bucket`` values)."""
    n = max(1, n)
    b = max(1, bucket)
    r = n if n <= b else -(-n // b) * b
    return min(r, pow2_bucket(n))


def max_exec_tokens(serve: ServeConfig, cfg: ModelConfig) -> int:
    """Worst-case tokens one Refresh dispatch materializes activations for.

    Token-packed engines run the iteration's Refresh set as ONE fused
    stream and round its real token sum up to ``token_bucket`` (bounded by
    the scheduler budget — which counts modality-frontend prefix rows as
    query tokens, so the stream bound covers vlm/audio too). Padded
    engines pay the full ``batch_bucket × (frontend_len + max_seq_len)``
    rectangle regardless of true lengths (``refresh_slots`` normalizes the
    0-means-unlimited cap).
    """
    if serve.varlen_pack and can_pack_tokens(cfg):
        tb = max(1, serve.token_bucket)
        return -(-serve.max_num_batched_tokens // tb) * tb
    fe = cfg.frontend_len if cfg.frontend_dim else 0
    return max(serve.max_num_batched_tokens,
               pow2_bucket(serve.refresh_slots) * (serve.max_seq_len + fe))


def reuse_exec_tokens(serve: ServeConfig, cfg: ModelConfig) -> int:
    """Worst-case tokens one Reuse dispatch materializes activations for.

    The reuse set is bounded by both ``max_slots`` and the scheduler budget
    (block tokens are scheduling currency; the Reuse stream is text-only —
    frontend prefixes never enter it). Packed engines — every family,
    vlm/audio included — round the request count to whole token buckets
    (exact below one bucket); padded engines pay the pow2 batch bucket."""
    Sb = max(1, serve.block_size)
    r_max = max(1, min(serve.max_slots, serve.max_num_batched_tokens // Sb))
    if serve.varlen_pack and can_pack_tokens(cfg):
        rb = max(1, serve.token_bucket // Sb)
        return token_bucket_round(r_max, rb) * Sb
    return pow2_bucket(r_max) * Sb


def backbone_activation_bytes(cfg: ModelConfig, serve: ServeConfig) -> int:
    """Workspace for attention/MLP over one packed batch. Scaled by the
    *executed* tokens of the widest stage — Refresh (query-token budget
    under varlen packing, the padded rectangle otherwise) or Reuse (packed
    block stream vs pow2 batch). The packed engine's smaller reservation is
    converted into KV slots by :func:`plan_memory`."""
    b = dtype_bytes(serve.dtype)
    T = max(max_exec_tokens(serve, cfg), reuse_exec_tokens(serve, cfg))
    width = max(cfg.d_ff, cfg.n_heads * cfg.resolved_head_dim,
                3 * cfg.d_model)
    return T * width * b * 2  # double-buffered


@dataclass(frozen=True)
class MemoryPlan:
    weights_bytes: int
    activation_bytes: int       # reserved (incl. logit stage under the mode)
    logit_bytes: int
    slot_bytes: int
    kv_pool_bytes: int
    max_slots: int

    def summary(self) -> str:
        gb = 1 << 30
        return (f"weights={self.weights_bytes/gb:.2f}GiB "
                f"act={self.activation_bytes/gb:.3f}GiB "
                f"(logit={self.logit_bytes/gb:.3f}GiB) "
                f"kv_pool={self.kv_pool_bytes/gb:.2f}GiB "
                f"slots={self.max_slots}")


def plan_memory(cfg: ModelConfig, serve: ServeConfig, hbm_bytes: int,
                guard_band: float = 0.03) -> MemoryPlan:
    """The offline profiler's output: activation reservation + KV pool size.

    Worst-case N_logit = one active block per resident request is bounded by
    slots·block; we budget for the scheduler-level cap instead:
    ``max_num_batched_tokens`` query tokens all needing logits.
    """
    weights = cfg.n_params() * dtype_bytes(cfg.dtype)
    n_logit_worst = serve.max_num_batched_tokens
    logit = logit_activation_bytes(cfg, serve, n_logit_worst)
    act = backbone_activation_bytes(cfg, serve) + logit
    guard = int(hbm_bytes * guard_band)
    slot = kv_slot_bytes(cfg, serve)
    pool = max(0, hbm_bytes - weights - act - guard)
    slots = min(serve.max_slots, pool // slot) if slot else serve.max_slots
    return MemoryPlan(weights, act, logit, slot, pool, int(slots))


# ---------------------------------------------------------------------------
# measured profiling (exact, via XLA compile)
# ---------------------------------------------------------------------------

def measure_logit_peak(cfg: ModelConfig, serve: ServeConfig,
                       n_tokens: int) -> dict:
    """Compile the decode stage in every C1 mode and read XLA's exact
    temp-buffer peak. Runs on any backend (no allocation: AOT only)."""
    import jax
    import jax.numpy as jnp
    from repro.models import lm_head as LM

    dtype = jnp.dtype(cfg.dtype)
    h = jax.ShapeDtypeStruct((n_tokens, cfg.d_model), dtype)
    params = {"table": jax.ShapeDtypeStruct((cfg.vocab_size, cfg.d_model), dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.ShapeDtypeStruct(
            (cfg.d_model, cfg.vocab_size), dtype)
    out = {}
    for mode in ("monolithic", "chunked", "fused"):
        def fn(params, h, mode=mode):
            return LM.decode_tokens(params, cfg, h,
                                    max_num_logits=serve.max_num_logits,
                                    mode=mode, vocab_tile=serve.vocab_tile)
        compiled = jax.jit(fn).lower(params, h).compile()
        ma = compiled.memory_analysis()
        out[mode] = int(ma.temp_size_in_bytes)
    return out
