"""Logit-Aware Activation Budgeting + Offline Memory Profiler (paper §4.2-4.3).

The profiler maps the memory envelope under worst-case serving pressure and
returns a :class:`MemoryPlan`: how much HBM is reserved for transient
activations (dominated by the logit stage) and how many KV slots fit in the
remainder. Because the logit reservation depends on ``logit_mode``, the plan
mechanically reproduces the paper's capacity coupling: decomposing the logit
tensor shrinks the activation reservation and converts the reclaimed bytes
into additional concurrent requests ("KV Cache Maximization").

Two profiling modes:
  * analytic  — closed-form worst-case byte accounting (used for capacity
    planning of the big dry-run configs; §3.2 arithmetic).
  * measured  — lower + compile the actual step functions and read
    ``memory_analysis().temp_size_in_bytes`` (exact under XLA's static
    planner; used by the logit-budget benchmark).

Mesh serving (``ServeConfig.mesh_shape``): every term is billed **per
device**. ``hbm_bytes`` is per-device HBM; weights follow the exact
``launch.sharding.Rules.params`` placement (evaluated shape-only over a
:class:`~repro.launch.mesh.SimMesh`, so a 2-GPU plan computes inside a 1-CPU
test process), KV-slot bytes follow the ``Rules.cache`` within-slot sharding
(KV heads over ``model`` when divisible, retained-length fallback otherwise
— a slot's *count* stays global: each device holds 1/TP of every slot), and
activation/logit reservations shard over heads/FFN/vocab when divisible.
That keeps the paper's §4.2-4.3 coupling live on an N-GPU mesh: per-device
bytes reclaimed from weights + activations convert into MORE slots, never
fewer. The data axis is billed conservatively (slots replicated over it).
"""
from __future__ import annotations

import functools

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ServeConfig


def dtype_bytes(dtype: str) -> int:
    return {"float32": 4, "bfloat16": 2, "float16": 2}[dtype]


def _tp_div(n: int, m: int) -> int:
    """Shard count the model axis contributes to a dim of size ``n`` —
    ``m`` on exact division (the Rules.div law), else 1 (replicated)."""
    return m if m > 1 and n and n % m == 0 and n >= m else 1


def _sharded_tree_bytes(mesh, shapes, specs, kv_quant: str = "none") -> int:
    """Per-device bytes of a (shape-tree, spec-tree) pair: each leaf's dims
    divide by the combined size of the mesh axes its spec names (ceil — the
    rules only shard on exact division anyway).

    ``kv_quant="int8"`` bills the leaves :func:`kernels.kv_quant.quant_mask`
    selects (PackedKV k/v) at 1 byte/element plus their per-(layer, slot)
    float32 scale — the same predicate the pool's runtime jits quantize
    with, so analytic capacity and allocated bytes cannot drift."""
    import jax

    def leaf_bytes(leaf, spec, quant):
        total = 1 if quant else leaf.dtype.itemsize
        for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            axes = (entry,) if isinstance(entry, str) else (entry or ())
            shards = 1
            for a in axes:
                shards *= mesh.shape[a]
            total *= -(-dim // shards)
        if quant:
            # [L, B] f32 scale; the layer/slot axes are never sharded by
            # the cache rules, so the scale is billed whole per device
            total += leaf.shape[0] * leaf.shape[1] * 4
        return total

    # a PartitionSpec is itself a tuple pytree — flatten the spec tree up to
    # the shape treedef so each P stays atomic alongside its shape leaf
    s_leaves, treedef = jax.tree.flatten(shapes)
    p_leaves = treedef.flatten_up_to(specs)
    if kv_quant == "none":
        flags = [False] * len(s_leaves)
    else:
        from repro.kernels.kv_quant import quant_mask
        flags = jax.tree.leaves(quant_mask(shapes))  # plain-bool leaves
    return int(sum(leaf_bytes(s, p, q)
                   for s, p, q in zip(s_leaves, p_leaves, flags)))


@functools.lru_cache(maxsize=None)
def weight_bytes_per_device(cfg: ModelConfig, mesh_shape) -> int:
    """Per-device parameter bytes under the ACTUAL serving placement.

    Shape-only: ``jax.eval_shape`` over ``init_params`` + the same
    ``Rules.params`` specs the engine places with, summed per shard (a
    :class:`SimMesh` stands in for the devices, so any mesh size can be
    planned from any host). ``mesh_shape=None`` bills one device."""
    import jax

    from repro.launch.mesh import SimMesh
    from repro.launch.sharding import Rules
    from repro.models import backbone as BB

    mesh = SimMesh(mesh_shape or (1, 1))
    shapes = jax.eval_shape(functools.partial(BB.init_params, cfg),
                            jax.random.PRNGKey(0))
    specs = Rules(cfg, mesh, train=False).params(shapes)
    return _sharded_tree_bytes(mesh, shapes, specs)


# ---------------------------------------------------------------------------
# analytic accounting
# ---------------------------------------------------------------------------

def logit_exec_tokens(serve: ServeConfig, n_logit_tokens: int) -> int:
    """Rows the engine's decode dispatch actually materializes for ``n``
    real hidden rows: token-bucket rounding under the packed engine (exact
    below one bucket — rows arrive in whole blocks), pow2 rounding on the
    padded oracle. The logit stage packs for *every* family under
    ``varlen_pack`` (the output head is family-agnostic)."""
    n = max(1, n_logit_tokens)
    if serve.varlen_pack:
        return token_bucket_round(n, serve.token_bucket)
    return pow2_bucket(n, lo=serve.block_size)


def logit_activation_bytes(cfg: ModelConfig, serve: ServeConfig,
                           n_logit_tokens: int) -> int:
    """Peak bytes of the output-projection stage under each C1 mode, billed
    by *executed* rows (the engine's bucketing policy, not the real count).
    Vocab-parallel under a mesh: each device materializes its [n, V/TP]
    shard (the argmax reduces across shards, never gathering [n, V])."""
    n_exec = logit_exec_tokens(serve, n_logit_tokens)
    v_pd = cfg.vocab_size // _tp_div(cfg.vocab_size, serve.mesh_model)
    if serve.logit_mode == "monolithic":
        # the paper's §3.2 boom: the full [N, V] tensor (f32 after softcap)
        return n_exec * v_pd * 4
    if serve.logit_mode == "chunked":
        return min(n_exec, serve.max_num_logits) * v_pd * 4
    # fused: the Pallas online kernel holds one [T_tile, V_tile] f32 block
    # per shard (vocab-sharded under a model axis > 1 — each shard scans its
    # V/TP slice and a cheap (max, index, logsumexp) reduce merges them)
    return 256 * serve.vocab_tile * 4


def _slot_cache_shapes(cfg: ModelConfig, serve: ServeConfig, retain: int,
                       batch: int = 1):
    """Shape-only cache pytree of ``batch`` slots — the engine pool's real
    per-slot geometry (family-specific leading layer axis included). The
    single shape model for the per-device billing here AND the Rules.cache
    property tests (``tests/test_sharding.py``)."""
    import jax
    import jax.numpy as jnp
    from repro.models.sparse_select import PackedKV
    sds = jax.ShapeDtypeStruct
    dt = jnp.dtype(serve.dtype)

    def kv_tree(nl):
        kshape = (nl, batch, cfg.n_kv_heads, retain, cfg.resolved_head_dim)
        return PackedKV(k=sds(kshape, dt), v=sds(kshape, dt),
                        pos=sds(kshape[:-1], jnp.int32),
                        valid=sds(kshape[:-1], jnp.bool_))

    def ssm_shapes():
        from repro.models.ssm import conv_channels
        st = sds((cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                  cfg.ssm_state), jnp.float32)
        cv = sds((cfg.n_layers, batch, cfg.ssm_conv_kernel - 1,
                  conv_channels(cfg)), dt)
        return st, cv

    if cfg.family == "ssm":
        from repro.models.ssm import SSMCache
        st, cv = ssm_shapes()
        return SSMCache(state=st, conv=cv)
    if cfg.family == "hybrid":
        from repro.models.hybrid import HybridCache, group_shape
        n_groups, _, _ = group_shape(cfg)
        st, cv = ssm_shapes()
        return HybridCache(ssm_state=st, conv=cv, kv=kv_tree(n_groups))
    return kv_tree(cfg.n_layers)


@functools.lru_cache(maxsize=None)
def kv_slot_bytes(cfg: ModelConfig, serve: ServeConfig) -> int:
    """Static per-request KV region (§4.5): r·L tokens, head-major dense.

    Per DEVICE under a mesh, evaluated from the ACTUAL ``Rules.cache``
    specs over the engine's real pool geometry — the same single source of
    truth the engine shards its slot pool with (one law, no analytic copy
    to drift): KV heads over ``model`` when divisible, else the retained
    length when divisible (the idle-TP fallback), else replicated; SSM
    states shard over heads, conv tails replicate; nothing shards over
    data (``data_parallel=False``, matching the pool). The retained length
    is the engine's ``min(retained_len, max_seq_len - block_size)``, so
    the divisibility decision is billed on the dimension the pool actually
    allocates. The slot *count* is global — ``plan_memory`` divides
    per-device pool bytes by this."""
    from repro.launch.mesh import SimMesh
    from repro.launch.sharding import Rules

    retain = min(serve.retained_len,
                 max(1, serve.max_seq_len - serve.block_size))
    mesh = SimMesh(serve.mesh_shape or (1, 1))
    specs = Rules(cfg, mesh, train=False).cache(1, retain,
                                                data_parallel=False)
    shapes = _slot_cache_shapes(cfg, serve, retain)
    return _sharded_tree_bytes(mesh, shapes, specs, kv_quant=serve.kv_quant)


def can_pack_tokens(cfg: ModelConfig) -> bool:
    """True when the engine's token-packed Refresh/Reuse paths apply to
    ``cfg`` — which is now EVERY family: attention archs run the
    segment-masked varlen attention stream, SSM/hybrid archs run the
    segment-reset varlen SSD scan (``models/ssm.varlen_ssd_scan`` / the
    Pallas ``kernels/ssm_scan`` kernel), and modality-frontend archs
    (vlm/audio) pack their ``frontend_len`` projected rows as a
    fixed-length prefix of each request's segment in the same flat stream.
    No family falls back to the padded oracle on the hot path, so every
    family is provisioned (and billed) by packed tokens under
    ``varlen_pack=True``. Kept as a function (single source of truth for
    the engine gate and the profiler's activation accounting) so a future
    family with a genuinely unpackable geometry has one place to opt out.
    """
    del cfg  # every family packs
    return True


def admission_block_reason(serve: ServeConfig, req) -> "str | None":
    """Why ``req`` can NEVER be admitted under ``serve`` (None = admittable).

    The single source of truth for structured rejection — checked by
    ``Engine.submit`` (fail fast, before the queue) and by both schedulers'
    ``plan()`` sweeps (so a never-admittable request cannot head-of-line
    block the FCFS queue). Geometry only: transient conditions (no free
    slot, budget consumed this iteration) are deferrals, not rejections."""
    if req.total_len > serve.max_seq_len:
        return (f"total_len {req.total_len} (prompt {req.prompt_len} + gen "
                f"{req.gen_len}) exceeds max_seq_len {serve.max_seq_len}")
    if req.refresh_len > serve.max_num_batched_tokens:
        return (f"Refresh cost {req.refresh_len} (frontend {req.frontend_len}"
                f" + total {req.total_len}) exceeds the token budget "
                f"max_num_batched_tokens={serve.max_num_batched_tokens}; "
                f"the request can never be scheduled")
    return None


def pow2_bucket(n: int, lo: int = 1) -> int:
    """Smallest power-of-two multiple of ``lo`` that is ≥ n (the static-shape
    bucketing policy shared by the engine's jit caches and this profiler)."""
    b = lo
    while b < n:
        b *= 2
    return b


def token_bucket_round(n: int, bucket: int) -> int:
    """Packed-stream rounding, the single source of truth for the engine's
    Reuse/logit buckets and this profiler's exec-token accounting: exact
    below one bucket, ceil to bucket multiples above, and never beyond the
    pow2 oracle bucket — the invariant the CI waste gate asserts (the cap
    only binds for non-pow2 ``bucket`` values)."""
    n = max(1, n)
    b = max(1, bucket)
    r = n if n <= b else -(-n // b) * b
    return min(r, pow2_bucket(n))


def max_exec_tokens(serve: ServeConfig, cfg: ModelConfig) -> int:
    """Worst-case tokens one Refresh dispatch materializes activations for.

    Token-packed engines run the iteration's Refresh set as ONE fused
    stream and round its real token sum up to ``token_bucket`` (bounded by
    the scheduler budget — which counts modality-frontend prefix rows as
    query tokens, so the stream bound covers vlm/audio too). Padded
    engines pay the full ``batch_bucket × (frontend_len + max_seq_len)``
    rectangle regardless of true lengths (``refresh_slots`` normalizes the
    0-means-unlimited cap).
    """
    if serve.varlen_pack and can_pack_tokens(cfg):
        tb = max(1, serve.token_bucket)
        return -(-serve.max_num_batched_tokens // tb) * tb
    fe = cfg.frontend_len if cfg.frontend_dim else 0
    return max(serve.max_num_batched_tokens,
               pow2_bucket(serve.refresh_slots) * (serve.max_seq_len + fe))


def reuse_exec_tokens(serve: ServeConfig, cfg: ModelConfig) -> int:
    """Worst-case tokens one Reuse dispatch materializes activations for.

    The reuse set is bounded by both ``max_slots`` and the scheduler budget
    (block tokens are scheduling currency; the Reuse stream is text-only —
    frontend prefixes never enter it). Packed engines — every family,
    vlm/audio included — round the request count to whole token buckets
    (exact below one bucket); padded engines pay the pow2 batch bucket."""
    Sb = max(1, serve.block_size)
    r_max = max(1, min(serve.max_slots, serve.max_num_batched_tokens // Sb))
    if serve.varlen_pack and can_pack_tokens(cfg):
        rb = max(1, serve.token_bucket // Sb)
        return token_bucket_round(r_max, rb) * Sb
    return pow2_bucket(r_max) * Sb


def backbone_activation_bytes(cfg: ModelConfig, serve: ServeConfig) -> int:
    """Workspace for attention/MLP over one packed batch. Scaled by the
    *executed* tokens of the widest stage — Refresh (query-token budget
    under varlen packing, the padded rectangle otherwise) or Reuse (packed
    block stream vs pow2 batch). Under a mesh the wide intermediates shard
    over the model axis (FFN hidden / attention heads; the [T, 3D] stream
    stays replicated), so the reservation is per device. The packed (and
    sharded) engine's smaller reservation is converted into KV slots by
    :func:`plan_memory`."""
    b = dtype_bytes(serve.dtype)
    m = serve.mesh_model
    T = max(max_exec_tokens(serve, cfg), reuse_exec_tokens(serve, cfg))
    width = max(cfg.d_ff // _tp_div(cfg.d_ff, m),
                cfg.n_heads * cfg.resolved_head_dim
                // _tp_div(cfg.n_heads, m),
                3 * cfg.d_model)
    return T * width * b * 2  # double-buffered


@dataclass(frozen=True)
class MemoryPlan:
    weights_bytes: int          # PER DEVICE (== global on 1 device/no mesh)
    activation_bytes: int       # reserved (incl. logit stage under the mode)
    logit_bytes: int
    slot_bytes: int             # per-device bytes of one (global) slot
    kv_pool_bytes: int
    max_slots: int              # global LOGICAL concurrent-request capacity
    mesh_devices: int = 1
    # memory-footprint multipliers (docs/memory.md): the physical slot count
    # the pool bytes actually fit, and the sharing/quantization knobs that
    # turned them into the logical ``max_slots`` above
    phys_slots: int = 0
    share_factor: float = 1.0
    kv_quant: str = "none"

    def summary(self) -> str:
        gb = 1 << 30
        mesh = f" mesh={self.mesh_devices}dev" if self.mesh_devices > 1 else ""
        share = (f" share={self.share_factor:.2f}x"
                 if self.share_factor != 1.0 else "")
        quant = f" kv={self.kv_quant}" if self.kv_quant != "none" else ""
        return (f"weights={self.weights_bytes/gb:.2f}GiB/dev "
                f"act={self.activation_bytes/gb:.3f}GiB "
                f"(logit={self.logit_bytes/gb:.3f}GiB) "
                f"kv_pool={self.kv_pool_bytes/gb:.2f}GiB "
                f"slots={self.max_slots}{mesh}{share}{quant}")


def plan_memory(cfg: ModelConfig, serve: ServeConfig, hbm_bytes: int,
                guard_band: float = 0.03,
                share_factor: float = 1.0) -> MemoryPlan:
    """The offline profiler's output: activation reservation + KV pool size.

    Worst-case N_logit = one active block per resident request is bounded by
    slots·block; we budget for the scheduler-level cap instead:
    ``max_num_batched_tokens`` query tokens all needing logits.

    Every term is per device (``hbm_bytes`` = one device's HBM). Under
    ``serve.mesh_shape`` the weight/KV-slot/activation bytes shrink by the
    sharded fractions, and the freed per-device headroom converts into MORE
    global slots — the §4.2-4.3 capacity coupling extended across a mesh.
    The slot pool shards its slot axis over the ``data`` axis (independent
    replica streams), so global capacity is per-replica slots × mesh_data.

    ``share_factor`` is the workload's measured logical/physical occupancy
    ratio (``data.workloads.prefix_share_factor``): with
    ``serve.prefix_sharing`` on, every physical slot the pool bytes fit
    backs that many logical residents on average, so the plan multiplies
    capacity before the ``serve.max_slots`` cap. int8 ``serve.kv_quant``
    instead shrinks ``slot_bytes`` (via ``kv_slot_bytes``) so more physical
    slots fit outright. Both multipliers are reported on the plan.
    """
    weights = weight_bytes_per_device(cfg, serve.mesh_shape)
    n_logit_worst = serve.max_num_batched_tokens
    logit = logit_activation_bytes(cfg, serve, n_logit_worst)
    act = backbone_activation_bytes(cfg, serve) + logit
    guard = int(hbm_bytes * guard_band)
    slot = kv_slot_bytes(cfg, serve)
    pool = max(0, hbm_bytes - weights - act - guard)
    replicas = max(1, serve.mesh_data)
    phys = replicas * (pool // slot) if slot else serve.max_slots
    share = share_factor if serve.prefix_sharing else 1.0
    slots = min(serve.max_slots, int(phys * share))
    return MemoryPlan(weights, act, logit, slot, pool, int(slots),
                      mesh_devices=serve.mesh_devices,
                      phys_slots=int(min(serve.max_slots, phys)),
                      share_factor=share, kv_quant=serve.kv_quant)


# ---------------------------------------------------------------------------
# measured profiling (exact, via XLA compile)
# ---------------------------------------------------------------------------

def measure_logit_peak(cfg: ModelConfig, serve: ServeConfig,
                       n_tokens: int) -> dict:
    """Compile the decode stage in every C1 mode and read XLA's exact
    temp-buffer peak. Runs on any backend (no allocation: AOT only)."""
    import jax
    import jax.numpy as jnp
    from repro.models import lm_head as LM

    dtype = jnp.dtype(cfg.dtype)
    h = jax.ShapeDtypeStruct((n_tokens, cfg.d_model), dtype)
    params = {"table": jax.ShapeDtypeStruct((cfg.vocab_size, cfg.d_model), dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.ShapeDtypeStruct(
            (cfg.d_model, cfg.vocab_size), dtype)
    out = {}
    for mode in ("monolithic", "chunked", "fused"):
        def fn(params, h, mode=mode):
            return LM.decode_tokens(params, cfg, h,
                                    max_num_logits=serve.max_num_logits,
                                    mode=mode, vocab_tile=serve.vocab_tile)
        from repro import jax_compat as JC
        compiled = JC.jit(fn).lower(params, h).compile()
        ma = compiled.memory_analysis()
        out[mode] = int(ma.temp_size_in_bytes)
    return out
