# dLLM-Serve core: diffusion engine, phase-multiplexed scheduler,
# logit-aware budgeting, head-centric sparse KV pool, baselines.
