"""Slot-granular static KV pool (paper §4.5 "Static Allocation and
Contiguous Storage").

Holds one device-resident cache pytree whose second axis is the request slot
(``[L, slots+1, ...]``; the extra slot is scratch for padded batch rows).
Refresh writes a freshly packed cache into a request's slot; Reuse gathers
slot slices for the scheduled sub-batch. The cache content is family-specific
(PackedKV / SSMCache / HybridCache) — the pool is shape-agnostic.

Mesh serving: the engine passes the pool a ``NamedSharding`` pytree built
from ``launch.sharding.Rules.cache`` (KV heads over the ``model`` axis when
divisible, retained-length fallback otherwise; the slot axis over ``data``
so each replica stream stores its slots locally — the engine pads the slot
count so the axis divides). The pool then allocates its backing pytree
sharded and pins the scatter's output layout with ``out_shardings`` so
repeated writes can never drift the pool off its planned placement —
per-device pool bytes are exactly what ``plan_memory`` billed; gathers land
in the data-replicated stream layout via ``gather_shardings``. Without
shardings (no mesh) nothing changes.

Slot lifecycle (robustness layer): :meth:`take` / :meth:`free` keep an
explicit free-set plus a per-slot **generation counter**. ``free`` bumps the
slot's generation, so a request holding a handle from before the free (a
preempted-then-recycled slot) can be detected: its recorded generation no
longer matches :meth:`generation`. Double-free and double-take raise — slot
leaks and aliasing are bugs, never silent.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import jax_compat as JC


class KVPool:
    def __init__(self, max_slots: int, shardings=None,
                 gather_shardings=None, pad_slots: int = 0,
                 compile_counter=None):
        """``shardings``: optional NamedSharding pytree matching the cache
        structure (leading slot axis included) — resolved lazily against the
        first Refresh output in :meth:`ensure`.

        ``gather_shardings``: optional NamedSharding pytree pinning the
        layout of gathered sub-batches (the engine's data-replicated stream
        layout — gathers cross from the slot-sharded pool into it).

        ``pad_slots``: extra never-allocated tail slots so a data-sharded
        pool's slot axis always divides the data axis; they are invisible to
        the slot ledger and never written.

        ``compile_counter``: optional Counter the pool's scatter/gather jits
        report compilations into (entries ``pool_write``/``pool_gather``) —
        the engine threads its per-instance retrace-sentinel counter here."""
        self.max_slots = max_slots
        self.scratch_slot = max_slots
        self.pad_slots = pad_slots
        self.shardings = shardings
        self.gather_shardings = gather_shardings
        self._compile_counter = compile_counter
        self.cache = None          # device pytree, slot axis = 1
        self._write = None
        self._gather = None
        # slot lifecycle ledger (content arrays above are allocation-lazy;
        # the ledger is live from construction so schedulers can use it
        # before the first Refresh materializes the pool)
        self._free = set(range(max_slots))
        self._gen = np.zeros(max_slots + 1, np.int64)

    # -- slot lifecycle ----------------------------------------------------
    @property
    def slots_in_use(self) -> list:
        return sorted(set(range(self.max_slots)) - self._free)

    def take(self, slot: int) -> int:
        """Claim ``slot``; returns its current generation (the handle a
        holder must present at gather time). Raises if already in use."""
        if slot not in self._free:
            raise RuntimeError(f"KVPool: slot {slot} taken while in use "
                               f"(free={sorted(self._free)})")
        self._free.discard(slot)
        return int(self._gen[slot])

    def free(self, slots: Sequence[int]) -> None:
        """Return slots to the pool, bumping each generation so stale
        handles become detectable. Raises on double-free."""
        for s in slots:
            if s in self._free:
                raise RuntimeError(f"KVPool: double-free of slot {s}")
            if not 0 <= s < self.max_slots:
                raise RuntimeError(f"KVPool: free of invalid slot {s}")
            self._free.add(s)
            self._gen[s] += 1

    def generation(self, slot: int) -> int:
        return int(self._gen[slot])

    def ensure(self, cache_example) -> None:
        """Lazily allocate the pool from the first Refresh output's shapes."""
        if self.cache is not None:
            return
        n = self.max_slots + 1 + self.pad_slots

        def alloc(c, ns=None):
            shape = (c.shape[0], n) + tuple(c.shape[2:])
            if ns is None:
                return jnp.zeros(shape, c.dtype)
            # allocate each device's shard directly — jnp.zeros(global) +
            # device_put would transiently hold the WHOLE pool on one
            # device, defeating the per-device plan at exactly the scale
            # the sharded pool enables
            shard = np.zeros(ns.shard_shape(shape), c.dtype)
            return jax.make_array_from_callback(shape, ns, lambda _: shard)

        cc = self._compile_counter
        if self.shardings is None:
            self.cache = jax.tree.map(alloc, cache_example)
            self._write = JC.jit(
                lambda pool, cache, slots: jax.tree.map(
                    lambda P, c: P.at[:, slots].set(c), pool, cache),
                donate_argnums=0, entry="pool_write", counter=cc)
        else:
            self.cache = jax.tree.map(alloc, cache_example, self.shardings)
            # pin the pool's planned layout across writes (donation keeps the
            # update in place; out_shardings keeps GSPMD from re-laying it out)
            self._write = JC.jit(
                lambda pool, cache, slots: jax.tree.map(
                    lambda P, c: P.at[:, slots].set(c), pool, cache),
                donate_argnums=0, out_shardings=self.shardings,
                entry="pool_write", counter=cc)
        if self.gather_shardings is None:
            self._gather = JC.jit(
                lambda pool, slots: jax.tree.map(lambda P: P[:, slots], pool),
                entry="pool_gather", counter=cc)
        else:
            # gathered sub-batches feed the data-replicated engine streams:
            # pin that layout so the slot-sharded pool's gather always lands
            # in the stage jits' expected placement
            self._gather = JC.jit(
                lambda pool, slots: jax.tree.map(lambda P: P[:, slots], pool),
                out_shardings=self.gather_shardings,
                entry="pool_gather", counter=cc)

    def nbytes(self) -> int:
        if self.cache is None:
            return 0
        return sum(x.nbytes for x in jax.tree.leaves(self.cache))

    def write(self, slots: Sequence[int], cache) -> None:
        self.ensure(cache)
        idx = jnp.asarray(np.asarray(slots, np.int32))
        self.cache = self._write(self.cache, cache, idx)

    def gather(self, slots: Sequence[int]):
        idx = jnp.asarray(np.asarray(slots, np.int32))
        return self._gather(self.cache, idx)
