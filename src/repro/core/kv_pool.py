"""Slot-granular static KV pool (paper §4.5 "Static Allocation and
Contiguous Storage").

Holds one device-resident cache pytree whose second axis is the request slot
(``[L, slots+1, ...]``; the extra slot is scratch for padded batch rows).
Refresh writes a freshly packed cache into a request's slot; Reuse gathers
slot slices for the scheduled sub-batch. The cache content is family-specific
(PackedKV / SSMCache / HybridCache) — the pool is shape-agnostic.

Mesh serving: the engine passes the pool a ``NamedSharding`` pytree built
from ``launch.sharding.Rules.cache`` (KV heads over the ``model`` axis when
divisible, retained-length fallback otherwise; the slot axis over ``data``
so each replica stream stores its slots locally — the engine pads the slot
count so the axis divides). The pool then allocates its backing pytree
sharded and pins the scatter's output layout with ``out_shardings`` so
repeated writes can never drift the pool off its planned placement —
per-device pool bytes are exactly what ``plan_memory`` billed; gathers land
in the data-replicated stream layout via ``gather_shardings``. Without
shardings (no mesh) nothing changes.

Slot lifecycle (robustness layer): :meth:`take` / :meth:`free` keep an
explicit free-set plus a per-slot **generation counter**. ``free`` bumps the
slot's generation, so a request holding a handle from before the free (a
preempted-then-recycled slot) can be detected: its recorded generation no
longer matches :meth:`generation`. Double-free and double-take raise — slot
leaks and aliasing are bugs, never silent.

Content-addressed sharing (``sharing=True``, docs/memory.md): a
:class:`~repro.core.share_ledger.ShareLedger` sits between logical slots
and physical rows. :meth:`write_shared` hashes nothing itself — the caller
supplies each request's content key — but redirects a write whose key is
already resident to the scratch row (skip) and records the logical slot as
a referrer of the owning row; :meth:`gather` resolves referrers to their
owner row; :meth:`free` releases references, promoting owned bytes to a
surviving referrer (one device row-copy, the ``pool_copy`` jit) before the
row is recycled — copy-on-write in both the divergent-Refresh and the
free-while-shared direction. The generation ledger is untouched: handles
stay logical, so preempt-and-requeue composes with sharing unchanged.

int8 slot storage (``kv_quant="int8"``): the pool's float KV leaves are
stored quantized with per-(layer, slot) scales (``kernels.kv_quant``).
Quantization runs inside the scatter jit; :meth:`gather` then returns the
**quantized view** (``{"data": ..., "scale": ...}``) so HBM traffic across
the gather stays int8 — the Reuse stages dequantize at their KV load
(``kernels.ops.dequantize_gathered``). Not yet composed with a device
mesh (the scale leaves need their own Rules-derived placement): the
constructor raises rather than guessing a layout.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import jax_compat as JC
from repro.core.share_ledger import ShareLedger


class KVPool:
    def __init__(self, max_slots: int, shardings=None,
                 gather_shardings=None, pad_slots: int = 0,
                 compile_counter=None, sharing: bool = False,
                 kv_quant: str = "none", donate_cache: bool = False):
        """``shardings``: optional NamedSharding pytree matching the cache
        structure (leading slot axis included) — resolved lazily against the
        first Refresh output in :meth:`ensure`.

        ``gather_shardings``: optional NamedSharding pytree pinning the
        layout of gathered sub-batches (the engine's data-replicated stream
        layout — gathers cross from the slot-sharded pool into it).

        ``pad_slots``: extra never-allocated tail slots so a data-sharded
        pool's slot axis always divides the data axis; they are invisible to
        the slot ledger and never written.

        ``compile_counter``: optional Counter the pool's scatter/gather/copy
        jits report compilations into (entries ``pool_write``/
        ``pool_gather``/``pool_copy``) — the engine threads its per-instance
        retrace-sentinel counter here.

        ``sharing``: enable the content-addressed share ledger (callers
        must then write via :meth:`write_shared` with per-slot keys).

        ``kv_quant``: ``"none"`` (bit-exact float storage) or ``"int8"``
        (per-slot-scale quantized KV leaves).

        ``donate_cache``: additionally donate the INCOMING refresh cache to
        the scatter jit (the pool buffer, argnum 0, is always donated — the
        update is in place either way). The engine opts in
        (``ServeConfig.donate_buffers``): its refresh outputs are
        single-use, dead once scattered. Callers that reuse a cache pytree
        across writes (the share-ledger property tests do) must leave this
        off — a donated tree's buffers are invalid after the call."""
        if kv_quant not in ("none", "int8"):
            raise ValueError(f"KVPool: kv_quant must be 'none' or 'int8', "
                             f"got {kv_quant!r}")
        if kv_quant != "none" and shardings is not None:
            raise NotImplementedError(
                "KVPool: int8 slot storage is not yet composed with a "
                "device mesh — the per-(layer, slot) scale leaves need "
                "their own Rules.cache-derived placement (planned; see "
                "docs/memory.md). Run quantized pools without mesh_shape.")
        self.max_slots = max_slots
        self.scratch_slot = max_slots
        self.pad_slots = pad_slots
        self.shardings = shardings
        self.gather_shardings = gather_shardings
        self._compile_counter = compile_counter
        self.kv_quant = kv_quant
        self._write_donate = (0, 1) if donate_cache else (0,)
        self.ledger: Optional[ShareLedger] = ShareLedger() if sharing \
            else None
        self.phys_peak = 0         # high-water distinct-owner occupancy
        self.cache = None          # device pytree, slot axis = 1
        self._write = None
        self._gather = None
        self._copy = None
        self._dtypes = None        # pre-quantization leaf dtypes (by index)
        # slot lifecycle ledger (content arrays above are allocation-lazy;
        # the ledger is live from construction so schedulers can use it
        # before the first Refresh materializes the pool)
        self._free = set(range(max_slots))
        self._gen = np.zeros(max_slots + 1, np.int64)

    # -- slot lifecycle ----------------------------------------------------
    @property
    def slots_in_use(self) -> list:
        return sorted(set(range(self.max_slots)) - self._free)

    @property
    def phys_slots_in_use(self) -> int:
        """Distinct content-holding rows: with sharing, the share ledger's
        owner count (the pool's REAL occupancy — referrers are free
        capacity); without, simply the logical slots in use."""
        if self.ledger is not None:
            return self.ledger.phys_slots
        return self.max_slots - len(self._free)

    def shared_refs(self, slot: int) -> int:
        """Live references backed by ``slot`` (≤ 1 when freeing it costs no
        promote copy; 0 without sharing). The scheduler's preemption victim
        preference reads this."""
        return self.ledger.refcount(slot) if self.ledger is not None else 0

    def take(self, slot: int) -> int:
        """Claim ``slot``; returns its current generation (the handle a
        holder must present at gather time). Raises if already in use."""
        if slot not in self._free:
            raise RuntimeError(f"KVPool: slot {slot} taken while in use "
                               f"(free={sorted(self._free)})")
        self._free.discard(slot)
        return int(self._gen[slot])

    def free(self, slots: Sequence[int]) -> None:
        """Return slots to the pool, bumping each generation so stale
        handles become detectable. Raises on double-free — before any
        mutation, so a bad batch never half-releases. With sharing, each
        slot's content reference is released first; bytes still referenced
        by other logical slots are promoted (device row-copy) before the
        owning row is recycled."""
        for s in slots:
            if s in self._free:
                raise RuntimeError(f"KVPool: double-free of slot {s}")
            if not 0 <= s < self.max_slots:
                raise RuntimeError(f"KVPool: free of invalid slot {s}")
        for s in slots:
            if self.ledger is not None:
                promote = self.ledger.release(s)
                if promote is not None:
                    self._copy_row(*promote)
            self._free.add(s)
            self._gen[s] += 1

    def generation(self, slot: int) -> int:
        return int(self._gen[slot])

    def ensure(self, cache_example) -> None:
        """Lazily allocate the pool from the first Refresh output's shapes."""
        if self.cache is not None:
            return
        n = self.max_slots + 1 + self.pad_slots

        def alloc(c, ns=None, dtype=None):
            shape = (c.shape[0], n) + tuple(c.shape[2:])
            dtype = dtype or c.dtype
            if ns is None:
                return jnp.zeros(shape, dtype)
            # allocate each device's shard directly — jnp.zeros(global) +
            # device_put would transiently hold the WHOLE pool on one
            # device, defeating the per-device plan at exactly the scale
            # the sharded pool enables
            shard = np.zeros(ns.shard_shape(shape), dtype)
            return jax.make_array_from_callback(shape, ns, lambda _: shard)

        cc = self._compile_counter
        if self.kv_quant == "int8":
            # int8 backing for the KV leaves + per-(layer, slot) scales;
            # quantize_slot_leaves runs INSIDE the scatter jit so the float
            # refresh output never lands in HBM as pool state
            from repro.kernels import kv_quant as KQ
            leaves, treedef = jax.tree.flatten(cache_example)
            flags = KQ.quant_leaf_flags(cache_example)
            self._dtypes = {str(i): leaf.dtype
                            for i, (leaf, q) in enumerate(zip(leaves, flags))
                            if q}
            data = jax.tree.unflatten(treedef, [
                alloc(c, dtype=jnp.int8 if q else None)
                for c, q in zip(leaves, flags)])
            scale = {str(i): jnp.zeros((leaves[int(i)].shape[0], n),
                                       jnp.float32) for i in self._dtypes}
            self.cache = {"data": data, "scale": scale}

            def wfn(pool, cache, slots):
                q, sc = KQ.quantize_slot_leaves(cache)
                return {
                    "data": jax.tree.map(
                        lambda P, c: P.at[:, slots].set(c), pool["data"], q),
                    "scale": {k: pool["scale"][k].at[:, slots].set(v)
                              for k, v in sc.items()},
                }

            self._write = JC.jit(wfn, donate_argnums=self._write_donate,
                                 entry="pool_write", counter=cc)
        elif self.shardings is None:
            self.cache = jax.tree.map(alloc, cache_example)
            self._write = JC.jit(
                lambda pool, cache, slots: jax.tree.map(
                    lambda P, c: P.at[:, slots].set(c), pool, cache),
                donate_argnums=self._write_donate, entry="pool_write",
                counter=cc)
        else:
            self.cache = jax.tree.map(alloc, cache_example, self.shardings)
            # pin the pool's planned layout across writes (donation keeps the
            # update in place; out_shardings keeps GSPMD from re-laying it out)
            self._write = JC.jit(
                lambda pool, cache, slots: jax.tree.map(
                    lambda P, c: P.at[:, slots].set(c), pool, cache),
                donate_argnums=self._write_donate,
                out_shardings=self.shardings,
                entry="pool_write", counter=cc)
        # every pool leaf — int8 data, f32 scales, float caches alike —
        # keeps the slot axis at position 1, so ONE gather/copy program
        # covers all storage modes
        if self.gather_shardings is None:
            self._gather = JC.jit(
                lambda pool, slots: jax.tree.map(lambda P: P[:, slots], pool),
                entry="pool_gather", counter=cc)
        else:
            # gathered sub-batches feed the data-replicated engine streams:
            # pin that layout so the slot-sharded pool's gather always lands
            # in the stage jits' expected placement
            self._gather = JC.jit(
                lambda pool, slots: jax.tree.map(lambda P: P[:, slots], pool),
                out_shardings=self.gather_shardings,
                entry="pool_gather", counter=cc)
        copy_kwargs = {} if self.shardings is None else \
            {"out_shardings": self.shardings}
        self._copy = JC.jit(
            lambda pool, src, dst: jax.tree.map(
                lambda P: P.at[:, dst].set(P[:, src]), pool),
            donate_argnums=0, entry="pool_copy", counter=cc, **copy_kwargs)

    @property
    def gathered_dtypes(self):
        """Pre-quantization leaf dtypes for ``dequantize_gathered`` (None
        until the pool materializes, or when storage is bit-exact)."""
        return self._dtypes

    def nbytes(self) -> int:
        if self.cache is None:
            return 0
        return sum(x.nbytes for x in jax.tree.leaves(self.cache))

    def _copy_row(self, src: int, dst: int) -> None:
        """Device row-copy ``src -> dst`` (COW promote). A no-op before the
        pool materializes — the ledger's bookkeeping alone is correct then,
        because an unmaterialized pool holds no bytes to preserve."""
        if self.cache is None:
            return
        self.cache = self._copy(self.cache, jnp.asarray(src, jnp.int32),
                                jnp.asarray(dst, jnp.int32))

    def warm_aux(self) -> None:
        """Warm the auxiliary ``pool_copy`` jit (scratch -> scratch, content
        irrelevant) so a sharing pool's first COW promote never compiles
        mid-serve — the retrace sentinel holds post-warmup compiles at
        zero. No-op without sharing (the copy path can't run)."""
        if self.ledger is not None and self.cache is not None:
            self._copy_row(self.scratch_slot, self.scratch_slot)

    def write(self, slots: Sequence[int], cache) -> None:
        self.ensure(cache)
        idx = jnp.asarray(np.asarray(slots, np.int32))
        self.cache = self._write(self.cache, cache, idx)

    def write_shared(self, slots: Sequence[int], cache,
                     keys: Sequence[Optional[bytes]]) -> None:
        """Content-aware Refresh write: one batched scatter in which every
        row whose key is already resident under an owner slot is redirected
        to the scratch row (the device write is skipped; the logical slot
        becomes a referrer). Divergent rows (a slot re-keyed while owning
        shared bytes) promote their old content to a surviving referrer
        BEFORE the scatter lands. ``keys[j] is None`` (warmup/padding rows)
        bypasses the ledger entirely."""
        if self.ledger is None:
            raise RuntimeError("KVPool: write_shared on a pool constructed "
                               "without sharing=True")
        self.ensure(cache)
        scatter = list(slots)
        for j, (s, key) in enumerate(zip(slots, keys)):
            if key is None or not 0 <= s < self.max_slots:
                continue
            do_write, promote = self.ledger.record_write(s, key)
            if promote is not None:
                self._copy_row(*promote)
            if not do_write:
                scatter[j] = self.scratch_slot
        idx = jnp.asarray(np.asarray(scatter, np.int32))
        self.cache = self._write(self.cache, cache, idx)
        self.phys_peak = max(self.phys_peak, self.ledger.phys_slots)

    def gather(self, slots: Sequence[int]):
        if self.ledger is not None:
            # referrers read their owner's row — the one place logical
            # slots translate to physical rows
            slots = [self.ledger.resolve(s) for s in slots]
        idx = jnp.asarray(np.asarray(slots, np.int32))
        return self._gather(self.cache, idx)
