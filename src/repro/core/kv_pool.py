"""Slot-granular static KV pool (paper §4.5 "Static Allocation and
Contiguous Storage").

Holds one device-resident cache pytree whose second axis is the request slot
(``[L, slots+1, ...]``; the extra slot is scratch for padded batch rows).
Refresh writes a freshly packed cache into a request's slot; Reuse gathers
slot slices for the scheduled sub-batch. The cache content is family-specific
(PackedKV / SSMCache / HybridCache) — the pool is shape-agnostic.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class KVPool:
    def __init__(self, max_slots: int):
        self.max_slots = max_slots
        self.scratch_slot = max_slots
        self.cache = None          # device pytree, slot axis = 1
        self._write = jax.jit(
            lambda pool, cache, slots: jax.tree.map(
                lambda P, c: P.at[:, slots].set(c), pool, cache),
            donate_argnums=0)
        self._gather = jax.jit(
            lambda pool, slots: jax.tree.map(lambda P: P[:, slots], pool))

    def ensure(self, cache_example) -> None:
        """Lazily allocate the pool from the first Refresh output's shapes."""
        if self.cache is not None:
            return
        n = self.max_slots + 1

        def alloc(c):
            shape = (c.shape[0], n) + tuple(c.shape[2:])
            return jnp.zeros(shape, c.dtype)

        self.cache = jax.tree.map(alloc, cache_example)

    def nbytes(self) -> int:
        if self.cache is None:
            return 0
        return sum(x.nbytes for x in jax.tree.leaves(self.cache))

    def write(self, slots: Sequence[int], cache) -> None:
        self.ensure(cache)
        idx = jnp.asarray(np.asarray(slots, np.int32))
        self.cache = self._write(self.cache, cache, idx)

    def gather(self, slots: Sequence[int]):
        idx = jnp.asarray(np.asarray(slots, np.int32))
        return self._gather(self.cache, idx)
