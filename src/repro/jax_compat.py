"""Version shims for the pinned jax 0.4.37 vs the newer mesh-context APIs.

The codebase targets the modern spelling (``jax.set_mesh`` /
``jax.sharding.get_abstract_mesh`` / ``jax.shard_map``) but the container pins
jax 0.4.37, where none of these exist. Each helper prefers the modern API and
falls back to the 0.4.37 equivalent:

  * mesh context — ``jax.set_mesh(mesh)`` vs the ``with mesh:`` resource
    context (``thread_resources.env.physical_mesh``).
  * active-mesh query — ``jax.sharding.get_abstract_mesh()`` vs reading the
    thread-resource physical mesh. Both are normalized to *None when no mesh
    is active* so call sites need a single emptiness check.
  * shard_map — ``jax.shard_map(..., check_vma=)`` vs
    ``jax.experimental.shard_map.shard_map(..., check_rep=)``.

This module is also the ONLY sanctioned doorway to the mesh/sharding API and
to ``jax.jit`` on the serving hot paths (the invariant ``repro.analysis``
lints for): ``P`` re-exports ``PartitionSpec`` so no other module imports
``jax.sharding`` directly, and :func:`jit` / :func:`jit_sharded` wrap
``jax.jit`` with an optional per-entry-point **compile counter** — the
retrace sentinel (``repro.analysis.retrace``) reads those counters to prove
the steady-state serving loop never recompiles after warmup.

Keep this module dependency-free (imported by kernels, models, and launch).
"""
from __future__ import annotations

import collections
import contextlib

import jax
from jax.sharding import PartitionSpec as P  # the sanctioned re-export

__all__ = [
    "P", "use_mesh", "get_active_mesh", "named_shardings", "jit",
    "jit_sharded", "shard_map", "compile_counts", "reset_compile_counts",
]

# On CPU (and some older backends) jax 0.4.37 cannot alias every donated
# buffer and warns "Some donated buffers were not usable" per dispatch.
# Donation is a pure lifetime hint — numerics are identical either way — so
# when a caller opts into donation we silence exactly that message once.
_DONATION_WARNING_FILTERED = False


def _enable_donation(jit_kwargs: dict, donate_argnums) -> dict:
    global _DONATION_WARNING_FILTERED
    if donate_argnums:
        jit_kwargs["donate_argnums"] = tuple(donate_argnums)
        if not _DONATION_WARNING_FILTERED:
            import warnings
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            _DONATION_WARNING_FILTERED = True
    return jit_kwargs

# process-global trace/compile counters, keyed by entry-point name. A jitted
# function's Python body runs exactly once per cache miss (each trace lowers
# and compiles), so counting body executions counts compilations — no
# version-fragile jax.monitoring hook needed on the pinned 0.4.37.
_compile_counts: collections.Counter = collections.Counter()


def compile_counts() -> dict:
    """Snapshot of the process-global per-entry compile counters."""
    return dict(_compile_counts)


def reset_compile_counts() -> None:
    _compile_counts.clear()


def _counting(fn, entry: str, counter):
    """Wrap ``fn`` so each *trace* (= jit cache miss = one XLA compilation)
    increments ``counter[entry]`` and the global ledger. The wrapper body
    only runs while jax traces, so steady-state cached calls cost nothing."""
    import functools

    @functools.wraps(fn)
    def traced(*args, **kwargs):
        _compile_counts[entry] += 1
        if counter is not None:
            counter[entry] += 1
        return fn(*args, **kwargs)

    return traced


def jit(fn=None, *, entry=None, counter=None, donate_argnums=(),
        **jit_kwargs):
    """``jax.jit`` through the compat layer (the lint-sanctioned spelling).

    ``entry`` names the jit entry point for the retrace sentinel: every
    compilation (trace) of the returned function increments the global
    ``compile_counts()`` ledger and, if given, ``counter[entry]`` (any
    Counter-like mapping — the engine passes its per-instance counter).
    Without ``entry`` this is a plain ``jax.jit``. Usable as a decorator
    (``@JC.jit`` / ``@functools.partial(JC.jit, static_argnames=...)``).

    ``donate_argnums`` marks per-call input buffers whose storage XLA may
    reuse for the outputs (the engine donates its per-iteration stream
    buffers so packed streams stop double-buffering — docs/engine.md).
    The caller contract: a donated argument's buffer is dead after the
    call; never re-pass or read it. Backends that can't alias a given
    donation silently keep a copy (the 0.4.37 CPU warning is filtered
    here), so donation never changes numerics — only buffer lifetime."""
    if fn is None:
        import functools
        return functools.partial(jit, entry=entry, counter=counter,
                                 donate_argnums=donate_argnums, **jit_kwargs)
    if entry is not None:
        fn = _counting(fn, entry, counter)
    return jax.jit(fn, **_enable_donation(jit_kwargs, donate_argnums))


@contextlib.contextmanager
def use_mesh(mesh):
    """Activate ``mesh`` for the dynamic scope (modern ``jax.set_mesh``)."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    else:
        # 0.4.37: Mesh is itself a context manager that installs the
        # thread-resource physical mesh (what get_active_mesh reads back).
        with mesh:
            yield mesh


def get_active_mesh():
    """The mesh of the enclosing ``use_mesh`` scope, or None.

    Returns an ``AbstractMesh`` on modern jax and a concrete ``Mesh`` on
    0.4.37 — both expose ``axis_names``/``shape``, which is all call sites
    use. Never returns an *empty* mesh object.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        m = fn()
        return None if m is None or m.empty else m
    from jax._src import mesh as _mesh_lib
    m = _mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def named_shardings(mesh, spec_tree):
    """PartitionSpec pytree -> NamedSharding pytree on ``mesh``.

    Specs are the *leaves* (a PartitionSpec is itself a pytree on some jax
    versions, so tree ops must treat it atomically)."""
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


def jit_sharded(fn, *, mesh, in_specs=None, out_specs=None, entry=None,
                counter=None, donate_argnums=(), **jit_kwargs):
    """``jax.jit`` with PartitionSpec-valued in/out shardings on ``mesh``.

    The serving engine's per-stage entry points thread their stage layouts
    through here: host inputs are auto-placed to the given in_specs (a spec
    leaf broadcasts over optional ``None`` args — verified on the pinned
    0.4.37), outputs are pinned to out_specs so downstream consumers (the
    slot pool above all) see a stable layout instead of whatever GSPMD
    propagation happened to pick. ``mesh=None`` is a plain ``jax.jit`` —
    the single-device path stays byte-for-byte the old code path.

    ``entry``/``counter`` hook the retrace sentinel exactly as in
    :func:`jit`: each compilation of the entry point is counted, so the
    engine can prove zero post-warmup recompilation. ``donate_argnums``
    follows the :func:`jit` donation contract (buffer dead after the call);
    donation composes with shardings — aliasing happens per device buffer."""
    if entry is not None:
        fn = _counting(fn, entry, counter)
    jit_kwargs = _enable_donation(jit_kwargs, donate_argnums)
    if mesh is None:
        return jax.jit(fn, **jit_kwargs)
    if in_specs is not None:
        jit_kwargs["in_shardings"] = named_shardings(mesh, in_specs)
    if out_specs is not None:
        jit_kwargs["out_shardings"] = named_shardings(mesh, out_specs)
    return jax.jit(fn, **jit_kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the 0.4.37 ``check_rep`` spelling fallback."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
