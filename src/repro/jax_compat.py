"""Version shims for the pinned jax 0.4.37 vs the newer mesh-context APIs.

The codebase targets the modern spelling (``jax.set_mesh`` /
``jax.sharding.get_abstract_mesh`` / ``jax.shard_map``) but the container pins
jax 0.4.37, where none of these exist. Each helper prefers the modern API and
falls back to the 0.4.37 equivalent:

  * mesh context — ``jax.set_mesh(mesh)`` vs the ``with mesh:`` resource
    context (``thread_resources.env.physical_mesh``).
  * active-mesh query — ``jax.sharding.get_abstract_mesh()`` vs reading the
    thread-resource physical mesh. Both are normalized to *None when no mesh
    is active* so call sites need a single emptiness check.
  * shard_map — ``jax.shard_map(..., check_vma=)`` vs
    ``jax.experimental.shard_map.shard_map(..., check_rep=)``.

Keep this module dependency-free (imported by kernels, models, and launch).
"""
from __future__ import annotations

import contextlib

import jax


@contextlib.contextmanager
def use_mesh(mesh):
    """Activate ``mesh`` for the dynamic scope (modern ``jax.set_mesh``)."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    else:
        # 0.4.37: Mesh is itself a context manager that installs the
        # thread-resource physical mesh (what get_active_mesh reads back).
        with mesh:
            yield mesh


def get_active_mesh():
    """The mesh of the enclosing ``use_mesh`` scope, or None.

    Returns an ``AbstractMesh`` on modern jax and a concrete ``Mesh`` on
    0.4.37 — both expose ``axis_names``/``shape``, which is all call sites
    use. Never returns an *empty* mesh object.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        m = fn()
        return None if m is None or m.empty else m
    from jax._src import mesh as _mesh_lib
    m = _mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the 0.4.37 ``check_rep`` spelling fallback."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
