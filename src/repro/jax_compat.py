"""Version shims for the pinned jax 0.4.37 vs the newer mesh-context APIs.

The codebase targets the modern spelling (``jax.set_mesh`` /
``jax.sharding.get_abstract_mesh`` / ``jax.shard_map``) but the container pins
jax 0.4.37, where none of these exist. Each helper prefers the modern API and
falls back to the 0.4.37 equivalent:

  * mesh context — ``jax.set_mesh(mesh)`` vs the ``with mesh:`` resource
    context (``thread_resources.env.physical_mesh``).
  * active-mesh query — ``jax.sharding.get_abstract_mesh()`` vs reading the
    thread-resource physical mesh. Both are normalized to *None when no mesh
    is active* so call sites need a single emptiness check.
  * shard_map — ``jax.shard_map(..., check_vma=)`` vs
    ``jax.experimental.shard_map.shard_map(..., check_rep=)``.

Keep this module dependency-free (imported by kernels, models, and launch).
"""
from __future__ import annotations

import contextlib

import jax


@contextlib.contextmanager
def use_mesh(mesh):
    """Activate ``mesh`` for the dynamic scope (modern ``jax.set_mesh``)."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    else:
        # 0.4.37: Mesh is itself a context manager that installs the
        # thread-resource physical mesh (what get_active_mesh reads back).
        with mesh:
            yield mesh


def get_active_mesh():
    """The mesh of the enclosing ``use_mesh`` scope, or None.

    Returns an ``AbstractMesh`` on modern jax and a concrete ``Mesh`` on
    0.4.37 — both expose ``axis_names``/``shape``, which is all call sites
    use. Never returns an *empty* mesh object.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        m = fn()
        return None if m is None or m.empty else m
    from jax._src import mesh as _mesh_lib
    m = _mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def named_shardings(mesh, spec_tree):
    """PartitionSpec pytree -> NamedSharding pytree on ``mesh``.

    Specs are the *leaves* (a PartitionSpec is itself a pytree on some jax
    versions, so tree ops must treat it atomically)."""
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


def jit_sharded(fn, *, mesh, in_specs=None, out_specs=None, **jit_kwargs):
    """``jax.jit`` with PartitionSpec-valued in/out shardings on ``mesh``.

    The serving engine's per-stage entry points thread their stage layouts
    through here: host inputs are auto-placed to the given in_specs (a spec
    leaf broadcasts over optional ``None`` args — verified on the pinned
    0.4.37), outputs are pinned to out_specs so downstream consumers (the
    slot pool above all) see a stable layout instead of whatever GSPMD
    propagation happened to pick. ``mesh=None`` is a plain ``jax.jit`` —
    the single-device path stays byte-for-byte the old code path."""
    if mesh is None:
        return jax.jit(fn, **jit_kwargs)
    if in_specs is not None:
        jit_kwargs["in_shardings"] = named_shardings(mesh, in_specs)
    if out_specs is not None:
        jit_kwargs["out_shardings"] = named_shardings(mesh, out_specs)
    return jax.jit(fn, **jit_kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the 0.4.37 ``check_rep`` spelling fallback."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
