"""Top-k routed Mixture-of-Experts FFN with two distribution strategies.

* ``gather`` (baseline): pure-pjit capacity-slot dispatch. Tokens are routed
  to ``[E, C]`` expert slots via an inverse-index gather, experts run as one
  batched einsum, and a combine gather weights results back. XLA partitions
  this automatically; the combine gather across the expert-sharded activation
  costs an all-gather over the model axis — measured and attacked in
  EXPERIMENTS.md §Perf.
* ``ep`` (optimized): explicit expert parallelism under ``shard_map``. Expert
  weights are sharded over the model axis; every model shard routes its
  (model-replicated) tokens to its local experts only and the combine is a
  single ``psum`` of activation-sized partials — the TPU-native analogue of
  the all-to-all EP exchange.

Routing uses softmax-then-top-k with renormalized gates and the standard
load-balance auxiliary loss (Switch/GShard form).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.jax_compat import P
from repro.models import layers as L


def init_moe_stack(cfg: ModelConfig, key: jax.Array, dtype) -> dict:
    nl, D, F, E = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": L.dense_init(ks[0], (nl, D, E), dtype),
        "w_gate": L.dense_init(ks[1], (nl, E, D, F), dtype),
        "w_up": L.dense_init(ks[2], (nl, E, D, F), dtype),
        "w_down": L.dense_init(ks[3], (nl, E, F, D), dtype),
    }


def _route(p, x2d, cfg: ModelConfig):
    """Router: returns (gates [T,k], expert_idx [T,k], aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x2d, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balance aux: E * sum_e f_e * p_e
    E = cfg.n_experts
    me = probs.mean(axis=0)                                    # [E]
    onehot = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)   # top-1 fraction
    ce = onehot.mean(axis=0)
    aux = E * jnp.sum(me * ce)
    return gates.astype(x2d.dtype), idx, aux


def _capacity(T: int, cfg: ModelConfig) -> int:
    c = int(T * cfg.experts_per_token * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def _dispatch_indices(idx, T: int, E: int, C: int):
    """Capacity-slot assignment. Returns (slot [T,k], keep [T,k], inv [E*C])."""
    k = idx.shape[1]
    flat = idx.reshape(-1)                                     # [T*k]
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                        # pos within expert
    pos = (pos * onehot).sum(-1)                                # [T*k]
    keep = pos < C
    slot = jnp.where(keep, flat * C + pos, E * C)               # E*C = drop bin
    token_of = jnp.arange(T, dtype=jnp.int32).repeat(k)
    inv = jnp.full((E * C + 1,), -1, jnp.int32).at[slot].set(token_of)[:-1]
    return slot.reshape(-1, k), keep.reshape(-1, k), inv


def _expert_ffn(p, x_disp, cfg: ModelConfig):
    """x_disp: [E, C, D] -> [E, C, D]."""
    act = jax.nn.gelu if cfg.activation == "gelu" else jax.nn.silu
    g = act(jnp.einsum("ecd,edf->ecf", x_disp, p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", x_disp, p["w_up"])
    return jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])


# ---------------------------------------------------------------------------
# baseline: pjit capacity-slot dispatch
# ---------------------------------------------------------------------------

def _moe_gather(p, x2d, cfg: ModelConfig):
    T, D = x2d.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    C = _capacity(T, cfg)
    gates, idx, aux = _route(p, x2d, cfg)
    slot, keep, inv = _dispatch_indices(idx, T, E, C)
    x_disp = jnp.where((inv >= 0)[:, None], x2d[jnp.maximum(inv, 0)], 0)
    x_disp = x_disp.reshape(E, C, D)
    y = _expert_ffn(p, x_disp, cfg).reshape(E * C, D)
    y = jnp.concatenate([y, jnp.zeros((1, D), y.dtype)], axis=0)  # drop bin
    y_tok = y[jnp.where(keep, slot, E * C)]                       # [T, k, D]
    out = jnp.einsum("tkd,tk->td", y_tok, gates * keep)
    return out, aux


# ---------------------------------------------------------------------------
# optimized: shard_map expert parallelism over the model axis
# ---------------------------------------------------------------------------

def _moe_ep(p, x2d, cfg: ModelConfig):
    """Expert-parallel MoE. Requires an active mesh with a 'model' axis;
    token activations replicated over 'model', expert weights sharded on E."""
    from repro.jax_compat import get_active_mesh
    mesh = get_active_mesh()
    assert mesh is not None, "moe_impl='ep' needs an active mesh (use_mesh)"
    m = mesh.shape["model"]
    E = cfg.n_experts
    assert E % m == 0, (E, m)
    E_loc = E // m
    data_axes = tuple(a for a in mesh.axis_names if a != "model")

    def shard(f_axes):
        return P(*f_axes)

    def body(router, wg, wu, wd, x_loc):
        # x_loc: [T_loc, D] (sharded over data axes, replicated over model)
        T_loc, D = x_loc.shape
        my = jax.lax.axis_index("model")
        pl = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        gates, idx, aux = _route({"router": router}, x_loc, cfg)
        # keep only assignments routed to my expert shard
        local = (idx // E_loc) == my
        idx_loc = jnp.where(local, idx - my * E_loc, E_loc)  # E_loc = drop
        C = _capacity(T_loc, cfg)  # same formula, local tokens
        k = cfg.experts_per_token
        flat = idx_loc.reshape(-1)
        onehot = jax.nn.one_hot(flat, E_loc + 1, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) - 1 * jnp.ones_like(onehot)) * onehot
        pos = pos.sum(-1)
        keep = (flat < E_loc) & (pos < C)
        slot = jnp.where(keep, flat * C + pos, E_loc * C)
        token_of = jnp.arange(T_loc, dtype=jnp.int32).repeat(k)
        inv = jnp.full((E_loc * C + 1,), -1, jnp.int32).at[slot].set(token_of)[:-1]
        x_disp = jnp.where((inv >= 0)[:, None], x_loc[jnp.maximum(inv, 0)], 0)
        x_disp = x_disp.reshape(E_loc, C, D)
        y = _expert_ffn({"w_gate": wg, "w_up": wu, "w_down": wd}, x_disp, cfg)
        y = jnp.concatenate([y.reshape(E_loc * C, D),
                             jnp.zeros((1, D), y.dtype)], axis=0)
        y_tok = y[jnp.where(keep.reshape(-1, k), slot.reshape(-1, k), E_loc * C)]
        part = jnp.einsum("tkd,tk->td", y_tok,
                          gates * keep.reshape(-1, k))
        # combine in bf16: halves the per-layer activation all-reduce (the
        # EP design's only per-layer collective); §Perf iteration A2
        out = jax.lax.psum(part.astype(jnp.bfloat16), "model")
        aux = jax.lax.pmean(aux, "model")
        return out.astype(x_loc.dtype), aux

    tok_spec = P(data_axes if data_axes else None, None)
    from repro.jax_compat import shard_map as _shard_map
    out, aux = _shard_map(
        body,
        mesh=mesh,
        in_specs=(P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None), tok_spec),
        out_specs=(tok_spec, P()),
        check_vma=False,
    )(p["router"], p["w_gate"], p["w_up"], p["w_down"], x2d)
    return out, aux


def moe_ffn(p, x, cfg: ModelConfig):
    """x: [B, S, D] -> ([B, S, D], aux_loss)."""
    B, S, D = x.shape
    x2d = x.reshape(B * S, D)
    if cfg.moe_impl == "ep":
        out, aux = _moe_ep(p, x2d, cfg)
    else:
        out, aux = _moe_gather(p, x2d, cfg)
    return out.reshape(B, S, D), aux
