"""Bidirectional diffusion transformer backbone (dense / moe / vlm / audio).

Two execution paths, mirroring the paper's two phases (§2.3):

* :func:`forward_full` — **Refresh**: full-sequence bidirectional forward.
  Optionally (serve mode) performs head-centric selection + packing *inside*
  the layer scan, emitting the dense packed KV cache without ever
  materializing the full KV stack across layers.
* :func:`forward_block` — **Reuse**: active-block queries attend to
  ``[packed cache ; live block KV]``; nothing is written back to the cache.

Layers are stacked on a leading ``[L, ...]`` axis and driven by ``lax.scan``
so the HLO stays small (critical for 80-layer configs) and remat policies
apply per layer.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models.sparse_select import PackedKV, select_and_pack


@dataclass(frozen=True)
class ServeContext:
    """Per-step serving metadata threaded through the layer scan."""
    block_size: int
    retain: int
    kernel_size: int = 3
    selection: str = "head"        # head | uniform | none
    q_chunk: int = L.DEFAULT_Q_CHUNK
    use_flash_kernel: bool = False  # Pallas packed-KV attention in Reuse steps
    reuse_concat: bool = False      # paper-naive single [cache;block] dispatch
    use_flash_refresh: bool = False  # Pallas flash kernel in Refresh steps


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer_stack(cfg: ModelConfig, key: jax.Array, dtype) -> dict:
    nl, D, F = cfg.n_layers, cfg.d_model, cfg.d_ff
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 12)
    p = {
        "attn_norm": jnp.zeros((nl, D), dtype),
        "mlp_norm": jnp.zeros((nl, D), dtype),
        "wq": L.dense_init(ks[0], (nl, D, H, dh), dtype),
        "wk": L.dense_init(ks[1], (nl, D, K, dh), dtype),
        "wv": L.dense_init(ks[2], (nl, D, K, dh), dtype),
        "wo": L.dense_init(ks[3], (nl, H, dh, D), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nl, H, dh), dtype)
        p["bk"] = jnp.zeros((nl, K, dh), dtype)
        p["bv"] = jnp.zeros((nl, K, dh), dtype)
    if cfg.is_moe:
        p.update(moe_lib.init_moe_stack(cfg, ks[4], dtype))
    else:
        p["w_gate"] = L.dense_init(ks[5], (nl, D, F), dtype)
        p["w_up"] = L.dense_init(ks[6], (nl, D, F), dtype)
        p["w_down"] = L.dense_init(ks[7], (nl, F, D), dtype)
    return p


# ---------------------------------------------------------------------------
# one transformer layer
# ---------------------------------------------------------------------------

def _qkv(p, x, cfg: ModelConfig, cos, sin):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dke->bske", x, p["wk"])
    v = jnp.einsum("bsd,dke->bske", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    return q, k, v


def _mlp(p, x, cfg: ModelConfig):
    """Returns (y, aux_loss). Dense MLPs have zero aux."""
    if cfg.is_moe:
        return moe_lib.moe_ffn(p, x, cfg)
    y = L.gated_mlp(x, p["w_gate"], p["w_up"], p["w_down"], cfg.activation)
    return y, jnp.float32(0.0)


def _layer_full(
    p: dict,
    x: jax.Array,              # [B, S, D]
    cfg: ModelConfig,
    positions: jax.Array,      # [B, S]
    cos, sin,
    is_local: jax.Array,       # scalar bool
    token_valid: jax.Array,    # [B, S]
    mask_mode: str,
    serve: Optional[ServeContext],
    block_start: Optional[jax.Array],   # [B] int32
) -> Tuple[jax.Array, Optional[PackedKV]]:
    x = L.constrain(x, "act3d")
    h = L.rms_norm(x, p["attn_norm"], cfg.rms_eps)
    q, k, v = _qkv(p, h, cfg, cos, sin)
    attn_out = L.attention(
        q, k, v, q_pos=positions, kv_pos=positions,
        kv_valid=token_valid, mask_mode=mask_mode,
        window=cfg.sliding_window, is_local=is_local,
        attn_softcap=cfg.attn_softcap,
        q_chunk=serve.q_chunk if serve else L.DEFAULT_Q_CHUNK,
        use_kernel=bool(serve and serve.use_flash_refresh))
    x = x + jnp.einsum("bshe,hed->bsd", attn_out, p["wo"])
    h2 = L.rms_norm(x, p["mlp_norm"], cfg.rms_eps)
    y, aux = _mlp(p, h2, cfg)
    x = L.constrain(x + y, "act3d")

    packed = None
    if serve is not None:
        Sb = serve.block_size
        B, S = positions.shape
        # slice the active block's queries (per-request block offsets)
        qb = jax.vmap(
            lambda qi, st: jax.lax.dynamic_slice_in_dim(qi, st, Sb, axis=0)
        )(q, block_start)
        ar = jnp.arange(S, dtype=jnp.int32)
        in_block = (ar[None] >= block_start[:, None]) & \
                   (ar[None] < block_start[:, None] + Sb)
        packed = select_and_pack(
            qb, k, v,
            retain=serve.retain, kernel_size=serve.kernel_size,
            mode=serve.selection, exclude=in_block | ~token_valid,
            token_valid=token_valid)
    return x, packed, aux


# ---------------------------------------------------------------------------
# full-sequence (Refresh / train) forward over the layer stack
# ---------------------------------------------------------------------------

def forward_full(
    stack: dict,
    cfg: ModelConfig,
    x: jax.Array,                      # [B, S, D] embedded input
    positions: jax.Array,              # [B, S] int32
    *,
    token_valid: Optional[jax.Array] = None,
    mask_mode: str = "bidirectional",
    serve: Optional[ServeContext] = None,
    block_start: Optional[jax.Array] = None,
    remat: bool = False,
) -> Tuple[jax.Array, Optional[PackedKV]]:
    B, S, D = x.shape
    if token_valid is None:
        token_valid = jnp.ones((B, S), bool)
    cos, sin = L.rope_tables(positions, cfg.resolved_head_dim, cfg.rope_theta)
    flags = L.layer_flags(cfg)

    def body(carry, scanned):
        p, is_local = scanned
        out, packed, aux = _layer_full(
            p, carry, cfg, positions, cos, sin, is_local,
            token_valid, mask_mode, serve, block_start)
        return out, (packed, aux)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)

    x, (packed, aux) = jax.lax.scan(body, x, (stack, flags))
    # packed: PackedKV with leading [L] axis (or None); aux: mean over layers
    return x, packed, jnp.mean(aux)


# ---------------------------------------------------------------------------
# block (Reuse) forward over a packed cache
# ---------------------------------------------------------------------------

def forward_block(
    stack: dict,
    cfg: ModelConfig,
    xb: jax.Array,                 # [B, Sb, D] embedded active block
    block_positions: jax.Array,    # [B, Sb] int32
    cache: PackedKV,               # leading [L] axis on every field
    *,
    serve: ServeContext,
    mask_mode: str = "bidirectional",
) -> jax.Array:
    cos, sin = L.rope_tables(block_positions, cfg.resolved_head_dim, cfg.rope_theta)
    flags = L.layer_flags(cfg)

    def body(carry, scanned):
        p, is_local, ck, cv, cpos, cvalid = scanned
        x = reuse_attention_layer(p, carry, cfg, cos, sin, block_positions,
                                  is_local, ck, cv, cpos, cvalid, mask_mode,
                                  use_kernel=serve.use_flash_kernel,
                                  concat=serve.reuse_concat)
        h2 = L.rms_norm(x, p["mlp_norm"], cfg.rms_eps)
        y, _ = _mlp(p, h2, cfg)
        return x + y, None

    xb, _ = jax.lax.scan(
        body, xb, (stack, flags, cache.k, cache.v, cache.pos, cache.valid))
    return xb


def reuse_attention_layer(p, x, cfg: ModelConfig, cos, sin, block_positions,
                          is_local, ck, cv, cpos, cvalid, mask_mode,
                          use_kernel: bool = False, concat: bool = False):
    """One Reuse-phase attention sublayer over [packed cache ; live block KV].

    Default (``concat=False``): **split attention** — one pass over the
    packed cache, one over the live block KV, merged exactly with flash-style
    (m, s) statistics. This is the TPU adaptation of the paper's single
    varlen dispatch: concatenating the live block onto a *sharded* retained
    axis forces XLA to gather the whole cache (measured: +17 GiB/device on
    decode_32k); two attentions + an exact merge keep the cache sharded.
    ``concat=True`` keeps the paper-naive single dispatch for comparison.
    """
    h = L.rms_norm(x, p["attn_norm"], cfg.rms_eps)
    q, k, v = _qkv(p, h, cfg, cos, sin)
    kb = k.transpose(0, 2, 1, 3)      # [B, K, Sb, dh]
    vb = v.transpose(0, 2, 1, 3)
    bpos_hm = jnp.broadcast_to(block_positions[:, None], kb.shape[:3])
    if concat:
        k_all = jnp.concatenate([ck, kb], axis=2)   # [B, K, R+Sb, dh]
        v_all = jnp.concatenate([cv, vb], axis=2)
        pos_all = jnp.concatenate([cpos, bpos_hm], axis=2)
        valid_all = jnp.concatenate(
            [cvalid, jnp.ones(kb.shape[:3], bool)], axis=2)
        attn_out = _attend_packed(q, k_all, v_all, pos_all, valid_all,
                                  block_positions, is_local, cfg, mask_mode,
                                  use_kernel=use_kernel)
    else:
        ok_c = _reuse_mask(cvalid, cpos, block_positions, is_local, cfg,
                           mask_mode)
        ok_b = _reuse_mask(jnp.ones(kb.shape[:3], bool), bpos_hm,
                           block_positions, is_local, cfg, mask_mode)
        if use_kernel:
            from repro.kernels import ops as kops
            B, Sb, H, dh = q.shape
            K = ck.shape[1]
            G = H // K
            qr = (q.reshape(B, Sb, K, G, dh).transpose(0, 2, 1, 3, 4)
                  .reshape(B, K, Sb * G, dh))
            o1, m1, s1 = kops.packed_flash_attention_stats(
                qr, ck, cv, ok_c, softcap=cfg.attn_softcap)
            o1 = o1.reshape(B, K, Sb, G, dh)
            m1 = m1.reshape(B, K, Sb, G)
            s1 = s1.reshape(B, K, Sb, G)
            m1 = m1.transpose(0, 1, 3, 2)
            s1 = s1.transpose(0, 1, 3, 2)
            o1 = o1.transpose(0, 1, 3, 2, 4)
        else:
            o1, m1, s1 = _attend_stats(q, ck, cv, ok_c, cfg)
        o2, m2, s2 = _attend_stats(q, kb, vb, ok_b, cfg)
        m = jnp.maximum(m1, m2)
        a1 = jnp.exp(m1 - m)[..., None]
        a2 = jnp.exp(m2 - m)[..., None]
        den = s1[..., None] * a1 + s2[..., None] * a2
        out = (o1 * a1 + o2 * a2) / jnp.maximum(den, 1e-30)
        B, Sb, H, dh = q.shape
        K = ck.shape[1]
        attn_out = (out.transpose(0, 3, 1, 2, 4)     # [B,Sb,K,G,dh]
                    .reshape(B, Sb, H, dh).astype(q.dtype))
    return x + jnp.einsum("bshe,hed->bsd", attn_out, p["wo"])


def _reuse_mask(valid, pos_hm, q_pos, is_local, cfg: ModelConfig, mask_mode):
    """[B, K, Sb, T] boolean mask for one side of the split attention."""
    ok = valid[:, :, None, :]
    if mask_mode == "causal":
        ok = ok & (q_pos[:, None, :, None] >= pos_hm[:, :, None, :])
    if cfg.sliding_window:
        dist = jnp.abs(q_pos[:, None, :, None] - pos_hm[:, :, None, :])
        ok = ok & jnp.where(is_local, dist <= cfg.sliding_window, True)
    return ok


def _attend_stats(q, k_hm, v_hm, ok, cfg: ModelConfig):
    """Unnormalized flash statistics for exact merging.

    q: [B, Sb, H, dh]; k_hm/v_hm: [B, K, T, dh]; ok: [B, K, Sb, T].
    Returns (o [B,K,G,Sb,dh] f32 unnormalized, m [B,K,G,Sb], s [B,K,G,Sb]).
    """
    B, Sb, H, dh = q.shape
    K = k_hm.shape[1]
    G = H // K
    scale = dh ** -0.5
    qg = q.reshape(B, Sb, K, G, dh)
    z = jnp.einsum("bqkgd,bktd->bkgqt", qg, k_hm).astype(jnp.float32) * scale
    if cfg.attn_softcap:
        z = cfg.attn_softcap * jnp.tanh(z / cfg.attn_softcap)
    z = jnp.where(ok[:, :, None], z, -jnp.inf)
    m = jnp.max(z, axis=-1)                       # [B,K,G,Sb]
    msafe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(z - msafe[..., None])
    p = jnp.where(jnp.isfinite(z), p, 0.0)
    s = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgqt,bktd->bkgqd", p.astype(v_hm.dtype), v_hm)
    return o.astype(jnp.float32), jnp.where(jnp.isfinite(m), m, -1e30), s


def _attend_packed(q, k_all, v_all, pos_all, valid_all, q_pos, is_local,
                   cfg: ModelConfig, mask_mode: str = "bidirectional",
                   use_kernel: bool = False):
    """Reuse-phase attention: [B,Sb,H,dh] queries over head-major packed KV.

    k_all/v_all: [B, K, T, dh]; pos_all/valid_all: [B, K, T].
    ``use_kernel`` dispatches to the Pallas flash kernel (same contract).
    """
    B, Sb, H, dh = q.shape
    K = k_all.shape[1]
    G = H // K
    ok = valid_all[:, :, None, :]                       # [B, K, 1, T]
    if mask_mode == "causal":
        ok = ok & (q_pos[:, None, :, None] >= pos_all[:, :, None, :])
    if cfg.sliding_window:
        dist = jnp.abs(q_pos[:, None, :, None] - pos_all[:, :, None, :])
        ok = ok & jnp.where(is_local, dist <= cfg.sliding_window, True)
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.packed_flash_attention(
            q, k_all, v_all, ok, softcap=cfg.attn_softcap)
    scale = dh ** -0.5
    qg = q.reshape(B, Sb, K, G, dh)
    s = jnp.einsum("bqkgd,bktd->bkgqt", qg, k_all).astype(jnp.float32) * scale
    if cfg.attn_softcap:
        s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
    s = jnp.where(ok[:, :, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v_all.dtype)
    out = jnp.einsum("bkgqt,bktd->bqkgd", p, v_all)
    return out.reshape(B, Sb, H, dh)
