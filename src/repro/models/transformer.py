"""Bidirectional diffusion transformer backbone (dense / moe / vlm / audio).

Two execution paths, mirroring the paper's two phases (§2.3):

* :func:`forward_full` — **Refresh**: full-sequence bidirectional forward.
  Optionally (serve mode) performs head-centric selection + packing *inside*
  the layer scan, emitting the dense packed KV cache without ever
  materializing the full KV stack across layers.
* :func:`forward_block` — **Reuse**: active-block queries attend to
  ``[packed cache ; live block KV]``; nothing is written back to the cache.

Layers are stacked on a leading ``[L, ...]`` axis and driven by ``lax.scan``
so the HLO stays small (critical for 80-layer configs) and remat policies
apply per layer.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models.sparse_select import (PackedKV, select_and_pack,
                                        select_and_pack_varlen)


@dataclass(frozen=True)
class ServeContext:
    """Per-step serving metadata threaded through the layer scan."""
    block_size: int
    retain: int
    kernel_size: int = 3
    selection: str = "head"        # head | uniform | none
    q_chunk: int = L.DEFAULT_Q_CHUNK
    use_flash_kernel: bool = False  # Pallas packed-KV attention in Reuse steps
    reuse_concat: bool = False      # paper-naive single [cache;block] dispatch
    use_flash_refresh: bool = False  # Pallas flash kernel in Refresh steps
    max_seq_len: int = 0            # per-request L cap (varlen-packed Refresh)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer_stack(cfg: ModelConfig, key: jax.Array, dtype) -> dict:
    nl, D, F = cfg.n_layers, cfg.d_model, cfg.d_ff
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 12)
    p = {
        "attn_norm": jnp.zeros((nl, D), dtype),
        "mlp_norm": jnp.zeros((nl, D), dtype),
        "wq": L.dense_init(ks[0], (nl, D, H, dh), dtype),
        "wk": L.dense_init(ks[1], (nl, D, K, dh), dtype),
        "wv": L.dense_init(ks[2], (nl, D, K, dh), dtype),
        "wo": L.dense_init(ks[3], (nl, H, dh, D), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nl, H, dh), dtype)
        p["bk"] = jnp.zeros((nl, K, dh), dtype)
        p["bv"] = jnp.zeros((nl, K, dh), dtype)
    if cfg.is_moe:
        p.update(moe_lib.init_moe_stack(cfg, ks[4], dtype))
    else:
        p["w_gate"] = L.dense_init(ks[5], (nl, D, F), dtype)
        p["w_up"] = L.dense_init(ks[6], (nl, D, F), dtype)
        p["w_down"] = L.dense_init(ks[7], (nl, F, D), dtype)
    return p


# ---------------------------------------------------------------------------
# one transformer layer
# ---------------------------------------------------------------------------

def _qkv(p, x, cfg: ModelConfig, cos, sin):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dke->bske", x, p["wk"])
    v = jnp.einsum("bsd,dke->bske", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    return q, k, v


def _mlp(p, x, cfg: ModelConfig):
    """Returns (y, aux_loss). Dense MLPs have zero aux."""
    if cfg.is_moe:
        return moe_lib.moe_ffn(p, x, cfg)
    y = L.gated_mlp(x, p["w_gate"], p["w_up"], p["w_down"], cfg.activation)
    return y, jnp.float32(0.0)


def _layer_full(
    p: dict,
    x: jax.Array,              # [B, S, D]
    cfg: ModelConfig,
    positions: jax.Array,      # [B, S]
    cos, sin,
    is_local: jax.Array,       # scalar bool
    token_valid: jax.Array,    # [B, S]
    mask_mode: str,
    serve: Optional[ServeContext],
    block_start: Optional[jax.Array],   # [B] int32
) -> Tuple[jax.Array, Optional[PackedKV]]:
    x = L.constrain(x, "act3d")
    h = L.rms_norm(x, p["attn_norm"], cfg.rms_eps)
    q, k, v = _qkv(p, h, cfg, cos, sin)
    attn_out = L.attention(
        q, k, v, q_pos=positions, kv_pos=positions,
        kv_valid=token_valid, mask_mode=mask_mode,
        window=cfg.sliding_window, is_local=is_local,
        attn_softcap=cfg.attn_softcap,
        q_chunk=serve.q_chunk if serve else L.DEFAULT_Q_CHUNK,
        use_kernel=bool(serve and serve.use_flash_refresh))
    x = x + jnp.einsum("bshe,hed->bsd", attn_out, p["wo"])
    h2 = L.rms_norm(x, p["mlp_norm"], cfg.rms_eps)
    y, aux = _mlp(p, h2, cfg)
    x = L.constrain(x + y, "act3d")

    packed = None
    if serve is not None:
        Sb = serve.block_size
        B, S = positions.shape
        # slice the active block's queries (per-request block offsets)
        qb = jax.vmap(
            lambda qi, st: jax.lax.dynamic_slice_in_dim(qi, st, Sb, axis=0)
        )(q, block_start)
        ar = jnp.arange(S, dtype=jnp.int32)
        in_block = (ar[None] >= block_start[:, None]) & \
                   (ar[None] < block_start[:, None] + Sb)
        packed = select_and_pack(
            qb, k, v,
            retain=serve.retain, kernel_size=serve.kernel_size,
            mode=serve.selection, exclude=in_block | ~token_valid,
            token_valid=token_valid)
    return x, packed, aux


# ---------------------------------------------------------------------------
# full-sequence (Refresh / train) forward over the layer stack
# ---------------------------------------------------------------------------

def forward_full(
    stack: dict,
    cfg: ModelConfig,
    x: jax.Array,                      # [B, S, D] embedded input
    positions: jax.Array,              # [B, S] int32
    *,
    token_valid: Optional[jax.Array] = None,
    mask_mode: str = "bidirectional",
    serve: Optional[ServeContext] = None,
    block_start: Optional[jax.Array] = None,
    remat: bool = False,
) -> Tuple[jax.Array, Optional[PackedKV]]:
    B, S, D = x.shape
    if token_valid is None:
        token_valid = jnp.ones((B, S), bool)
    cos, sin = L.rope_tables(positions, cfg.resolved_head_dim, cfg.rope_theta)
    flags = L.layer_flags(cfg)

    def body(carry, scanned):
        p, is_local = scanned
        out, packed, aux = _layer_full(
            p, carry, cfg, positions, cos, sin, is_local,
            token_valid, mask_mode, serve, block_start)
        return out, (packed, aux)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)

    x, (packed, aux) = jax.lax.scan(body, x, (stack, flags))
    # packed: PackedKV with leading [L] axis (or None); aux: mean over layers
    return x, packed, jnp.mean(aux)


# ---------------------------------------------------------------------------
# token-packed (varlen) Refresh forward — the paper's flattened engine (§4.1)
# ---------------------------------------------------------------------------

def _attend_packed_stream(
    q: jax.Array,              # [1, T, H, dh]
    k: jax.Array,              # [1, T, K, dh]
    v: jax.Array,              # [1, T, K, dh]
    positions: jax.Array,      # [1, T]
    seg_ids: jax.Array,        # [1, T]
    token_valid: jax.Array,    # [1, T]
    cfg: ModelConfig,
    is_local: jax.Array,
    serve: ServeContext,
    mask_mode: str = "bidirectional",
) -> jax.Array:
    """Segment-masked attention over the flat packed stream (jnp fallback to
    the Pallas varlen kernel).

    Requests are contiguous in the stream and at most ``max_seq_len`` long,
    so a ``q_chunk`` query slab can only share a segment with tokens inside a
    ``q_chunk + 2·max_seq_len`` window around it. Each chunk attends to that
    window only — the XLA-level analogue of the kernel's tile-skip, keeping
    fallback FLOPs ~ ``T·(c + 2L)`` instead of ``T²``.
    """
    _, T_len, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    c = min(serve.q_chunk, T_len)
    win = min(T_len, c + 2 * serve.max_seq_len)
    if T_len % c or win >= T_len:
        # window covers everything (or ragged chunking): plain segment path
        return L.attention(
            q, k, v, q_pos=positions, kv_pos=positions,
            kv_valid=token_valid, q_seg=seg_ids, kv_seg=seg_ids,
            mask_mode=mask_mode, window=cfg.sliding_window,
            is_local=is_local, attn_softcap=cfg.attn_softcap, q_chunk=c)
    nq = T_len // c
    scale = dh ** -0.5
    # window start: the first token of the chunk's first segment, clamped so
    # the static-size slice stays in bounds. seg start = chunk_start - pos.
    starts = jnp.arange(nq, dtype=jnp.int32) * c
    seg_start = starts - positions[0, starts]
    w0 = jnp.clip(seg_start, 0, T_len - win)
    qg = q[0].reshape(nq, c, K, G, dh)
    qp = positions[0].reshape(nq, c)
    qs = seg_ids[0].reshape(nq, c)

    def chunk(args):
        qc, qpc, qsc, w = args
        kc = jax.lax.dynamic_slice_in_dim(k[0], w, win, axis=0)
        vc = jax.lax.dynamic_slice_in_dim(v[0], w, win, axis=0)
        kpc = jax.lax.dynamic_slice_in_dim(positions[0], w, win, axis=0)
        ksc = jax.lax.dynamic_slice_in_dim(seg_ids[0], w, win, axis=0)
        kvc = jax.lax.dynamic_slice_in_dim(token_valid[0], w, win, axis=0)
        z = jnp.einsum("qkgd,skd->kgqs", qc, kc).astype(jnp.float32) * scale
        if cfg.attn_softcap:
            z = cfg.attn_softcap * jnp.tanh(z / cfg.attn_softcap)
        ok = (qsc[:, None] == ksc[None, :]) & kvc[None, :]
        if mask_mode == "causal":
            ok = ok & (qpc[:, None] >= kpc[None, :])
        if cfg.sliding_window:
            dist = jnp.abs(qpc[:, None] - kpc[None, :])
            ok = ok & jnp.where(is_local, dist <= cfg.sliding_window, True)
        z = jnp.where(ok[None, None], z, -1e30)
        p = jax.nn.softmax(z, axis=-1).astype(vc.dtype)
        return jnp.einsum("kgqs,skd->qkgd", p, vc)

    out = jax.lax.map(chunk, (qg, qp, qs, w0))     # [nq, c, K, G, dh]
    return out.reshape(1, T_len, H, dh).astype(q.dtype)


def _layer_full_packed(
    p: dict,
    x: jax.Array,              # [1, T, D] flat packed stream
    cfg: ModelConfig,
    positions: jax.Array,      # [1, T] position within the owning request
    seg_ids: jax.Array,        # [1, T] ascending request id (sentinel on pad)
    token_valid: jax.Array,    # [1, T]
    cos, sin,
    is_local: jax.Array,
    serve: ServeContext,
    cu_seqlens: jax.Array,     # [R] int32 flat start offset per request
    gather_rows: jax.Array,    # [R, S_sel] flat row of request r's token s
    valid_sel: jax.Array,      # [R, S_sel]
    block_rows: jax.Array,     # [R, Sb] flat rows of each active block
    in_block: jax.Array,       # [R, S_sel]
    mask_mode: str = "bidirectional",
) -> Tuple[jax.Array, PackedKV, jax.Array]:
    x = L.constrain(x, "act3d")
    h = L.rms_norm(x, p["attn_norm"], cfg.rms_eps)
    q, k, v = _qkv(p, h, cfg, cos, sin)
    if serve.use_flash_refresh or serve.use_flash_kernel:
        from repro.kernels import ops as kops
        attn_out = kops.flash_varlen_attention(
            q[0], k[0], v[0], seg_ids=seg_ids[0], positions=positions[0],
            kv_valid=token_valid[0], window=cfg.sliding_window,
            is_local=is_local, causal=mask_mode == "causal",
            softcap=cfg.attn_softcap)[None]
    else:
        attn_out = _attend_packed_stream(
            q, k, v, positions, seg_ids, token_valid, cfg, is_local, serve,
            mask_mode=mask_mode)
    x = x + jnp.einsum("bshe,hed->bsd", attn_out, p["wo"])
    h2 = L.rms_norm(x, p["mlp_norm"], cfg.rms_eps)
    y, aux = _mlp(p, h2, cfg)
    x = L.constrain(x + y, "act3d")

    # head-centric select/pack reads the flat stream in place: scoring is
    # segment-masked on the stream (kernel tile-skip / chunked jnp) and only
    # the `retain` winners are gathered into the per-slot dense cache — the
    # padded [R, max_seq_len, K, dh] K/V views are never materialized.
    qb = q[0][block_rows]          # [R, Sb, H, dh]
    packed = select_and_pack_varlen(
        qb, k[0], v[0], seg_ids[0], cu_seqlens, gather_rows, valid_sel,
        retain=serve.retain, kernel_size=serve.kernel_size,
        mode=serve.selection, exclude=in_block | ~valid_sel,
        use_kernel=bool(serve.use_flash_refresh or serve.use_flash_kernel))
    return x, packed, aux


def packed_block_rows(cu_seqlens, block_start, block_size: int,
                      total_len: int):
    """Flat stream rows of each request's active block ([R, Sb], clipped so
    padding requests gather in-bounds)."""
    return jnp.clip(
        cu_seqlens[:, None] + block_start[:, None]
        + jnp.arange(block_size, dtype=jnp.int32)[None], 0, total_len - 1)


def packed_refresh_geometry(cu_seqlens, seq_lens, block_start, total_len,
                            serve: ServeContext):
    """Per-request gather geometry of a packed Refresh stream, shared by the
    attention and hybrid packed forwards: the select/pack view rows
    (``gather_rows``/``valid_sel``), each active block's flat rows, and the
    in-block exclusion mask. Returns
    (gather_rows [R, S_sel], valid_sel [R, S_sel], block_rows [R, Sb],
    in_block [R, S_sel])."""
    S_sel = serve.max_seq_len
    Sb = serve.block_size
    ar = jnp.arange(S_sel, dtype=jnp.int32)
    gather_rows = jnp.clip(cu_seqlens[:, None] + ar[None], 0, total_len - 1)
    valid_sel = ar[None] < seq_lens[:, None]
    block_rows = packed_block_rows(cu_seqlens, block_start, Sb, total_len)
    in_block = (ar[None] >= block_start[:, None]) & \
               (ar[None] < block_start[:, None] + Sb)
    return gather_rows, valid_sel, block_rows, in_block


def forward_full_packed(
    stack: dict,
    cfg: ModelConfig,
    x: jax.Array,                  # [1, T, D] embedded packed stream
    positions: jax.Array,          # [1, T] int32
    seg_ids: jax.Array,            # [1, T] int32
    token_valid: jax.Array,        # [1, T] bool
    cu_seqlens: jax.Array,         # [R] int32 flat start offset per request
    seq_lens: jax.Array,           # [R] int32 true length per request
    block_start: jax.Array,        # [R] int32 block offset within the request
    serve: ServeContext,
) -> Tuple[jax.Array, PackedKV, jax.Array]:
    """Token-packed Refresh over the layer stack.

    One ragged ``[T, ...]`` stream replaces the padded ``[B, S]`` batch;
    requests are delimited by ``cu_seqlens``/``seg_ids`` and attention is
    segment-masked (kernel or chunked-jnp — never an [S, S] bias). The
    stream is family-agnostic: for the modality-frontend archs the caller
    (``backbone.serve_refresh_packed``) embeds each segment as
    ``[frontend prefix ; text]`` and widens ``serve.max_seq_len`` by
    ``frontend_len`` — prefix rows are ordinary stream rows here (they
    attend, score, and are retainable). Returns (flat hidden [1, T, D],
    per-request PackedKV with leading [L] axis, aux).
    """
    assert serve.max_seq_len > 0, "packed path needs ServeContext.max_seq_len"
    _, T, _ = x.shape
    cos, sin = L.rope_tables(positions, cfg.resolved_head_dim, cfg.rope_theta)
    flags = L.layer_flags(cfg)
    gather_rows, valid_sel, block_rows, in_block = packed_refresh_geometry(
        cu_seqlens, seq_lens, block_start, T, serve)

    def body(carry, scanned):
        p, is_local = scanned
        out, packed, aux = _layer_full_packed(
            p, carry, cfg, positions, seg_ids, token_valid, cos, sin,
            is_local, serve, cu_seqlens, gather_rows, valid_sel, block_rows,
            in_block)
        return out, (packed, aux)

    x, (packed, aux) = jax.lax.scan(body, x, (stack, flags))
    return x, packed, jnp.mean(aux)


# ---------------------------------------------------------------------------
# block (Reuse) forward over a packed cache
# ---------------------------------------------------------------------------

def forward_block(
    stack: dict,
    cfg: ModelConfig,
    xb: jax.Array,                 # [B, Sb, D] embedded active block
    block_positions: jax.Array,    # [B, Sb] int32
    cache: PackedKV,               # leading [L] axis on every field
    *,
    serve: ServeContext,
    mask_mode: str = "bidirectional",
) -> jax.Array:
    cos, sin = L.rope_tables(block_positions, cfg.resolved_head_dim, cfg.rope_theta)
    flags = L.layer_flags(cfg)

    def body(carry, scanned):
        p, is_local, ck, cv, cpos, cvalid = scanned
        x = reuse_attention_layer(p, carry, cfg, cos, sin, block_positions,
                                  is_local, ck, cv, cpos, cvalid, mask_mode,
                                  use_kernel=serve.use_flash_kernel,
                                  concat=serve.reuse_concat)
        h2 = L.rms_norm(x, p["mlp_norm"], cfg.rms_eps)
        y, _ = _mlp(p, h2, cfg)
        return x + y, None

    xb, _ = jax.lax.scan(
        body, xb, (stack, flags, cache.k, cache.v, cache.pos, cache.valid))
    return xb


def forward_block_packed(
    stack: dict,
    cfg: ModelConfig,
    xb: jax.Array,                 # [R, Sb, D] embedded active blocks
    block_positions: jax.Array,    # [R, Sb] int32 absolute positions
    cache: PackedKV,               # leading [L] axis, batch axis = R
    *,
    serve: ServeContext,
) -> jax.Array:
    """Token-packed Reuse over the layer stack (whole-iteration packing).

    The iteration's R active blocks form one ragged ``[R·Sb]`` query stream
    (R is rounded to the token-bucket granularity by the engine — never a
    pow2 batch bucket). With ``use_flash_kernel`` each layer runs ONE flat
    cross-attention dispatch: packed queries against the flat per-request
    ``[retain ; live block]`` KV stream, non-owned KV tiles skipped in-kernel
    (FLOPs ~ R·Sb·(retain+Sb), not R²·...). Without the kernel, the layer
    falls back to the exact split-attention math batched over the same R —
    identical FLOPs, XLA-level dispatch. Bidirectional only (the attention
    families are bidirectional diffusion LMs; the causal hybrid family has
    its own packed Reuse in :func:`repro.models.hybrid.forward_block_packed`
    built on the same flat dispatch)."""
    R, Sb, D = xb.shape
    cos, sin = L.rope_tables(block_positions, cfg.resolved_head_dim,
                             cfg.rope_theta)
    flags = L.layer_flags(cfg)
    Cr = cache.k.shape[3]
    q_seg = jnp.repeat(jnp.arange(R, dtype=jnp.int32), Sb)
    kv_seg = jnp.repeat(jnp.arange(R, dtype=jnp.int32), Cr + Sb)

    def body(carry, scanned):
        p, is_local, ck, cv, cpos, cvalid = scanned
        if serve.use_flash_kernel:
            x = _reuse_attention_layer_flat(
                p, carry, cfg, cos, sin, block_positions, is_local,
                ck, cv, cpos, cvalid, q_seg, kv_seg)
        else:
            x = reuse_attention_layer(p, carry, cfg, cos, sin,
                                      block_positions, is_local, ck, cv,
                                      cpos, cvalid, "bidirectional",
                                      concat=serve.reuse_concat)
        h2 = L.rms_norm(x, p["mlp_norm"], cfg.rms_eps)
        y, _ = _mlp(p, h2, cfg)
        return x + y, None

    xb, _ = jax.lax.scan(
        body, xb, (stack, flags, cache.k, cache.v, cache.pos, cache.valid))
    return xb


def _reuse_attention_layer_flat(p, x, cfg: ModelConfig, cos, sin,
                                block_positions, is_local, ck, cv, cpos,
                                cvalid, q_seg, kv_seg,
                                mask_mode: str = "bidirectional"):
    """One packed-Reuse attention sublayer as a single flat varlen dispatch.

    x: [R, Sb, D]; ck/cv: [R, K, Cr, dh] gathered slot caches. The KV stream
    interleaves each request's retained cache with its live block KV —
    requests stay contiguous (segment-ascending), so the cross kernel's
    tile-skip bounds compute by Σ (retain + Sb) per owning request.
    ``mask_mode="causal"`` serves the hybrid family's causal shared block."""
    R, Sb, _ = x.shape
    K, Cr, dh = ck.shape[1], ck.shape[2], ck.shape[3]
    h = L.rms_norm(x, p["attn_norm"], cfg.rms_eps)
    q, k, v = _qkv(p, h, cfg, cos, sin)
    H = q.shape[2]
    kb = k.transpose(0, 2, 1, 3)          # [R, K, Sb, dh]
    vb = v.transpose(0, 2, 1, 3)
    bpos_hm = jnp.broadcast_to(block_positions[:, None], (R, K, Sb))
    k_all = jnp.concatenate([ck, kb], axis=2)      # [R, K, Cr+Sb, dh]
    v_all = jnp.concatenate([cv, vb], axis=2)
    pos_all = jnp.concatenate([cpos, bpos_hm], axis=2)
    valid_all = jnp.concatenate(
        [cvalid, jnp.ones((R, K, Sb), bool)], axis=2)
    Tkv = R * (Cr + Sb)
    k_s = k_all.transpose(1, 0, 2, 3).reshape(K, Tkv, dh)
    v_s = v_all.transpose(1, 0, 2, 3).reshape(K, Tkv, dh)
    pos_s = pos_all.transpose(1, 0, 2).reshape(K, Tkv)
    valid_s = valid_all.transpose(1, 0, 2).reshape(K, Tkv)
    from repro.kernels import ops as kops
    out = kops.flash_varlen_cross_attention(
        q.reshape(R * Sb, H, dh), k_s, v_s,
        q_seg=q_seg, q_pos=block_positions.reshape(-1),
        kv_seg=kv_seg, kv_pos=pos_s, kv_valid=valid_s,
        window=cfg.sliding_window, is_local=is_local,
        causal=mask_mode == "causal", softcap=cfg.attn_softcap)
    attn_out = out.reshape(R, Sb, H, dh)
    return x + jnp.einsum("bshe,hed->bsd", attn_out, p["wo"])


def reuse_attention_layer(p, x, cfg: ModelConfig, cos, sin, block_positions,
                          is_local, ck, cv, cpos, cvalid, mask_mode,
                          use_kernel: bool = False, concat: bool = False):
    """One Reuse-phase attention sublayer over [packed cache ; live block KV].

    Default (``concat=False``): **split attention** — one pass over the
    packed cache, one over the live block KV, merged exactly with flash-style
    (m, s) statistics. This is the TPU adaptation of the paper's single
    varlen dispatch: concatenating the live block onto a *sharded* retained
    axis forces XLA to gather the whole cache (measured: +17 GiB/device on
    decode_32k); two attentions + an exact merge keep the cache sharded.
    ``concat=True`` keeps the paper-naive single dispatch for comparison.
    """
    h = L.rms_norm(x, p["attn_norm"], cfg.rms_eps)
    q, k, v = _qkv(p, h, cfg, cos, sin)
    kb = k.transpose(0, 2, 1, 3)      # [B, K, Sb, dh]
    vb = v.transpose(0, 2, 1, 3)
    bpos_hm = jnp.broadcast_to(block_positions[:, None], kb.shape[:3])
    if concat:
        k_all = jnp.concatenate([ck, kb], axis=2)   # [B, K, R+Sb, dh]
        v_all = jnp.concatenate([cv, vb], axis=2)
        pos_all = jnp.concatenate([cpos, bpos_hm], axis=2)
        valid_all = jnp.concatenate(
            [cvalid, jnp.ones(kb.shape[:3], bool)], axis=2)
        attn_out = _attend_packed(q, k_all, v_all, pos_all, valid_all,
                                  block_positions, is_local, cfg, mask_mode,
                                  use_kernel=use_kernel)
    else:
        ok_c = _reuse_mask(cvalid, cpos, block_positions, is_local, cfg,
                           mask_mode)
        ok_b = _reuse_mask(jnp.ones(kb.shape[:3], bool), bpos_hm,
                           block_positions, is_local, cfg, mask_mode)
        if use_kernel:
            from repro.kernels import ops as kops
            B, Sb, H, dh = q.shape
            K = ck.shape[1]
            G = H // K
            qr = (q.reshape(B, Sb, K, G, dh).transpose(0, 2, 1, 3, 4)
                  .reshape(B, K, Sb * G, dh))
            o1, m1, s1 = kops.packed_flash_attention_stats(
                qr, ck, cv, ok_c, softcap=cfg.attn_softcap)
            o1 = o1.reshape(B, K, Sb, G, dh)
            m1 = m1.reshape(B, K, Sb, G)
            s1 = s1.reshape(B, K, Sb, G)
            m1 = m1.transpose(0, 1, 3, 2)
            s1 = s1.transpose(0, 1, 3, 2)
            o1 = o1.transpose(0, 1, 3, 2, 4)
        else:
            o1, m1, s1 = _attend_stats(q, ck, cv, ok_c, cfg)
        o2, m2, s2 = _attend_stats(q, kb, vb, ok_b, cfg)
        m = jnp.maximum(m1, m2)
        a1 = jnp.exp(m1 - m)[..., None]
        a2 = jnp.exp(m2 - m)[..., None]
        den = s1[..., None] * a1 + s2[..., None] * a2
        out = (o1 * a1 + o2 * a2) / jnp.maximum(den, 1e-30)
        B, Sb, H, dh = q.shape
        K = ck.shape[1]
        attn_out = (out.transpose(0, 3, 1, 2, 4)     # [B,Sb,K,G,dh]
                    .reshape(B, Sb, H, dh).astype(q.dtype))
    return x + jnp.einsum("bshe,hed->bsd", attn_out, p["wo"])


def _reuse_mask(valid, pos_hm, q_pos, is_local, cfg: ModelConfig, mask_mode):
    """[B, K, Sb, T] boolean mask for one side of the split attention."""
    ok = valid[:, :, None, :]
    if mask_mode == "causal":
        ok = ok & (q_pos[:, None, :, None] >= pos_hm[:, :, None, :])
    if cfg.sliding_window:
        dist = jnp.abs(q_pos[:, None, :, None] - pos_hm[:, :, None, :])
        ok = ok & jnp.where(is_local, dist <= cfg.sliding_window, True)
    return ok


def _attend_stats(q, k_hm, v_hm, ok, cfg: ModelConfig):
    """Unnormalized flash statistics for exact merging.

    q: [B, Sb, H, dh]; k_hm/v_hm: [B, K, T, dh]; ok: [B, K, Sb, T].
    Returns (o [B,K,G,Sb,dh] f32 unnormalized, m [B,K,G,Sb], s [B,K,G,Sb]).
    """
    B, Sb, H, dh = q.shape
    K = k_hm.shape[1]
    G = H // K
    scale = dh ** -0.5
    qg = q.reshape(B, Sb, K, G, dh)
    z = jnp.einsum("bqkgd,bktd->bkgqt", qg, k_hm).astype(jnp.float32) * scale
    if cfg.attn_softcap:
        z = cfg.attn_softcap * jnp.tanh(z / cfg.attn_softcap)
    z = jnp.where(ok[:, :, None], z, -jnp.inf)
    m = jnp.max(z, axis=-1)                       # [B,K,G,Sb]
    msafe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(z - msafe[..., None])
    p = jnp.where(jnp.isfinite(z), p, 0.0)
    s = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgqt,bktd->bkgqd", p.astype(v_hm.dtype), v_hm)
    return o.astype(jnp.float32), jnp.where(jnp.isfinite(m), m, -1e30), s


def _attend_packed(q, k_all, v_all, pos_all, valid_all, q_pos, is_local,
                   cfg: ModelConfig, mask_mode: str = "bidirectional",
                   use_kernel: bool = False):
    """Reuse-phase attention: [B,Sb,H,dh] queries over head-major packed KV.

    k_all/v_all: [B, K, T, dh]; pos_all/valid_all: [B, K, T].
    ``use_kernel`` dispatches to the Pallas flash kernel (same contract).
    """
    B, Sb, H, dh = q.shape
    K = k_all.shape[1]
    G = H // K
    ok = valid_all[:, :, None, :]                       # [B, K, 1, T]
    if mask_mode == "causal":
        ok = ok & (q_pos[:, None, :, None] >= pos_all[:, :, None, :])
    if cfg.sliding_window:
        dist = jnp.abs(q_pos[:, None, :, None] - pos_all[:, :, None, :])
        ok = ok & jnp.where(is_local, dist <= cfg.sliding_window, True)
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.packed_flash_attention(
            q, k_all, v_all, ok, softcap=cfg.attn_softcap)
    scale = dh ** -0.5
    qg = q.reshape(B, Sb, K, G, dh)
    s = jnp.einsum("bqkgd,bktd->bkgqt", qg, k_all).astype(jnp.float32) * scale
    if cfg.attn_softcap:
        s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
    s = jnp.where(ok[:, :, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v_all.dtype)
    out = jnp.einsum("bkgqt,bktd->bqkgd", p, v_all)
    return out.reshape(B, Sb, H, dh)
