"""Head-centric vs uniform sparse KV selection (paper §2.4 eq.5, §4.5 eq.6).

This is the algorithmic core of contribution C3. During a Refresh step the
layer scan calls :func:`select_and_pack` with the freshly computed full-seq
K/V and the active-block queries; it returns a *physically dense* packed cache
``[B, K, R, dh]`` (head-major, contiguous — the paper's "Static Allocation and
Contiguous Storage"). The index map is transient: it is used once here and
never stored, so Reuse-phase attention reads the cache sequentially with zero
gathers.

GQA note: selection operates at KV-head granularity. Per-head scores from the
G query heads of a group are max-aggregated onto their KV head, so "head-
centric" means one independent token set per *KV head* (the finest granularity
at which a packed KV layout can differ). With MQA (K=1, gemma-2b) this
degenerates to a single shared set — documented in DESIGN.md §5.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class PackedKV(NamedTuple):
    k: jax.Array        # [B, K, R, dh]  post-RoPE keys, densely packed
    v: jax.Array        # [B, K, R, dh]
    pos: jax.Array      # [B, K, R] int32  original token positions
    valid: jax.Array    # [B, K, R] bool


def head_scores(
    q_block: jax.Array,   # [B, Sb, H, dh] active-block queries
    k_full: jax.Array,    # [B, S, K, dh]  full-sequence keys (post-RoPE)
    kernel_size: int,
    s_chunk: int = 4096,
    valid: jax.Array | None = None,   # [B, S] bool
) -> jax.Array:
    """Per-KV-head importance scores, eq.(6):  S_{h,j} = maxpool_w(Q_b · K_j).

    Returns [B, K, S] float32. The K-axis is processed in ``s_chunk`` tiles
    so the [B, K, G, Sb, S] alignment tensor never materializes (at 32k
    prefill it would be multiple GiB/device).

    ``valid`` masks raw scores to -inf BEFORE the max-pool: invalid rows
    (batch padding in the padded path, the *next request's* tokens in the
    token-packed path) must not leak relevance into valid boundary tokens
    through the pooling window — otherwise a request's retained set would
    depend on what it happens to be batched with.
    """
    B, Sb, H, dh = q_block.shape
    K = k_full.shape[2]
    G = H // K
    qg = q_block.reshape(B, Sb, K, G, dh)

    def tile(kc):  # kc: [B, c, K, dh] -> [B, K, c]
        r = jnp.einsum("bqkgd,bskd->bkgqs", qg, kc).astype(jnp.float32)
        return r.max(axis=(2, 3))

    S = k_full.shape[1]
    if S > s_chunk and S % s_chunk == 0:
        kc = k_full.reshape(B, S // s_chunk, s_chunk, K, dh)
        raw = jax.lax.map(tile, kc.transpose(1, 0, 2, 3, 4))
        raw = raw.transpose(1, 2, 0, 3).reshape(B, K, S)
    else:
        raw = tile(k_full)  # [B, K, S]
    if valid is not None:
        raw = jnp.where(valid[:, None, :], raw, -jnp.inf)
    return _local_maxpool(raw, kernel_size)


def _local_maxpool(raw: jax.Array, kernel_size: int) -> jax.Array:
    """Local max-pooling with window w along the last axis (captures
    neighbourhood relevance, eq.6). Edges pad with -inf — the same sentinel
    masking uses, so invalid/foreign neighbours can never leak in."""
    w = kernel_size
    if w > 1:
        pads = [raw]
        for off in range(1, w // 2 + 1):
            pads.append(jnp.pad(raw[..., off:], [(0, 0)] * (raw.ndim - 1)
                                + [(0, off)], constant_values=-jnp.inf))
            pads.append(jnp.pad(raw[..., :-off], [(0, 0)] * (raw.ndim - 1)
                                + [(off, 0)], constant_values=-jnp.inf))
        raw = jnp.stack(pads).max(axis=0)
    return raw


def head_scores_varlen(
    q_block: jax.Array,   # [R, Sb, H, dh] active-block queries per request
    k_flat: jax.Array,    # [T, K, dh]  flat packed-stream keys (post-RoPE)
    seg_ids: jax.Array,   # [T] int32 ascending owner id (PAD_SEG on pad)
    kernel_size: int,
    s_chunk: int = 4096,
    use_kernel: bool = False,
) -> jax.Array:
    """Per-KV-head importance scores against the flat token-packed stream.

    Returns [R, K, T] float32: request r's eq.(6) scores at its own stream
    positions, ``-inf`` everywhere else (foreign requests and bucket
    padding). Masking happens BEFORE the max-pool, so a request's retained
    set cannot depend on what it is packed with — the varlen equivalent of
    the ``valid`` pre-masking in :func:`head_scores`. The Pallas kernel path
    tile-skips non-owned key tiles; the jnp fallback chunks the stream axis
    so the [R, K, G, Sb, c] alignment tensor never materializes at full T.
    """
    if use_kernel:
        from repro.kernels import ops as kops
        raw = kops.head_score_varlen(q_block, k_flat, seg_ids)
    else:
        R, Sb, H, dh = q_block.shape
        T, K = k_flat.shape[0], k_flat.shape[1]
        G = H // K
        qg = q_block.reshape(R, Sb, K, G, dh)
        rid = jnp.arange(R, dtype=jnp.int32)

        def tile(args):  # kc: [c, K, dh], sc: [c] -> [R, K, c]
            kc, sc = args
            r = jnp.einsum("rqkgd,skd->rkgqs", qg, kc).astype(jnp.float32)
            r = r.max(axis=(2, 3))
            own = sc[None, :] == rid[:, None]              # [R, c]
            return jnp.where(own[:, None, :], r, -jnp.inf)

        if T > s_chunk:
            # pad the stream to whole chunks with a -1 segment sentinel (it
            # matches no request id, so pad scores are -inf) — the [R, K, G,
            # Sb, c] alignment tensor never materializes at full T
            pad = (-T) % s_chunk
            kp = jnp.pad(k_flat, ((0, pad), (0, 0), (0, 0)))
            sp = jnp.pad(seg_ids, (0, pad), constant_values=-1)
            Tp = T + pad
            kc = kp.reshape(Tp // s_chunk, s_chunk, K, dh)
            sc = sp.reshape(Tp // s_chunk, s_chunk)
            raw = jax.lax.map(tile, (kc, sc))              # [n, R, K, c]
            raw = raw.transpose(1, 2, 0, 3).reshape(R, K, Tp)[:, :, :T]
        else:
            raw = tile((k_flat, seg_ids))
    return _local_maxpool(raw, kernel_size)


def select_indices(
    scores: jax.Array,       # [B, K, S] float32
    retain: int,
    *,
    mode: str,               # "head" (ours) | "uniform" (Sparse-dLLM)
    exclude: jax.Array,      # [B, S] bool — active block / invalid positions
) -> jax.Array:
    """Top-k token indices per KV head. Returns [B, K, R] int32 (sorted)."""
    neg = jnp.float32(-1e30)
    scores = jnp.where(exclude[:, None, :], neg, scores)
    if mode == "uniform":
        # Sparse-dLLM eq.(5): aggregate across heads -> one shared index set
        shared = scores.sum(axis=1, keepdims=True)          # [B, 1, S]
        shared = jnp.broadcast_to(shared, scores.shape)
        scores = shared
    _, idx = jax.lax.top_k(scores, retain)                   # [B, K, R]
    # sort selected indices so the packed cache preserves sequence order
    return jnp.sort(idx, axis=-1).astype(jnp.int32)


def pack(
    idx: jax.Array,        # [B, K, R]
    k_full: jax.Array,     # [B, S, K, dh]
    v_full: jax.Array,     # [B, S, K, dh]
    token_valid: jax.Array,  # [B, S] bool
) -> PackedKV:
    """Gather the retained tokens into the dense head-major layout.

    The single gather here is the *only* indirection in the whole C3 pipeline;
    it runs once per Refresh, after which Reuse reads contiguously.
    """
    kh = k_full.transpose(0, 2, 1, 3)   # [B, K, S, dh]
    vh = v_full.transpose(0, 2, 1, 3)
    pk = jnp.take_along_axis(kh, idx[..., None], axis=2)
    pv = jnp.take_along_axis(vh, idx[..., None], axis=2)
    val = jnp.take_along_axis(
        jnp.broadcast_to(token_valid[:, None, :], idx.shape[:2] + token_valid.shape[1:]),
        idx, axis=2)
    return PackedKV(pk, pv, idx, val)


def select_and_pack(
    q_block: jax.Array,
    k_full: jax.Array,
    v_full: jax.Array,
    *,
    retain: int,
    kernel_size: int,
    mode: str,
    exclude: jax.Array,
    token_valid: jax.Array,
) -> PackedKV:
    if mode == "none":
        # dense retention (r = 1.0): keep everything outside the block, packed
        # to `retain` slots by score so shapes stay static.
        scores = jnp.zeros(k_full.shape[:2], jnp.float32)[:, None, :]
        scores = jnp.broadcast_to(scores, (k_full.shape[0], k_full.shape[2], k_full.shape[1]))
        scores = scores - jnp.arange(k_full.shape[1], dtype=jnp.float32)[None, None, :] * 1e-6
        idx = select_indices(scores, retain, mode="uniform", exclude=exclude)
    else:
        scores = head_scores(q_block, k_full, kernel_size, valid=token_valid)
        idx = select_indices(scores, retain, mode=mode, exclude=exclude)
    packed = pack(idx, k_full, v_full, token_valid)
    # positions excluded (block/invalid) may still be picked when fewer than
    # `retain` candidates exist; mark them invalid so attention masks them.
    excl = jnp.take_along_axis(
        jnp.broadcast_to(exclude[:, None, :], idx.shape[:2] + exclude.shape[1:]),
        idx, axis=2)
    return PackedKV(packed.k, packed.v, packed.pos, packed.valid & ~excl)


def select_and_pack_varlen(
    q_block: jax.Array,      # [R, Sb, H, dh] active-block queries per request
    k_flat: jax.Array,       # [T, K, dh] flat packed-stream keys
    v_flat: jax.Array,       # [T, K, dh]
    seg_ids: jax.Array,      # [T] int32 ascending owner id
    cu_seqlens: jax.Array,   # [R] int32 flat start offset per request
    gather_rows: jax.Array,  # [R, S_sel] flat row of request r's token s
    valid_sel: jax.Array,    # [R, S_sel] bool (s < seq_len)
    *,
    retain: int,
    kernel_size: int,
    mode: str,
    exclude: jax.Array,      # [R, S_sel] bool (active block / invalid)
    use_kernel: bool = False,
) -> PackedKV:
    """C3 select/pack reading the flat token-packed stream in place.

    Scoring and pooling run on the stream itself (kernel tile-skip or
    chunked jnp); only the per-request *score windows* ([R, S_sel] f32 —
    K·4 bytes/token) are gathered for the top-k, and the final pack gathers
    exactly the ``retain`` winners from the flat K/V. The padded path's
    ``[R, max_seq_len, K, dh]`` K AND V gathers never happen — the last
    rectangular intermediate on the packed Refresh path. Selection semantics
    (scores, pooling edges, exclusion, tie order) match :func:`select_and_pack`
    per request, so both paths retain the same tokens."""
    R, S_sel = gather_rows.shape
    T, K = k_flat.shape[0], k_flat.shape[1]
    if mode == "none":
        # dense retention: position-ordered packing, no scoring (same math
        # as the padded branch — scores never touch K)
        scores = jnp.zeros((R, K, S_sel), jnp.float32)
        scores = scores - jnp.arange(S_sel, dtype=jnp.float32)[None, None, :] * 1e-6
        idx = select_indices(scores, retain, mode="uniform", exclude=exclude)
    else:
        raw = head_scores_varlen(q_block, k_flat, seg_ids, kernel_size,
                                 use_kernel=use_kernel)      # [R, K, T]
        rows = jnp.broadcast_to(gather_rows[:, None, :], (R, K, S_sel))
        scores = jnp.take_along_axis(raw, rows, axis=2)      # [R, K, S_sel]
        idx = select_indices(scores, retain, mode=mode, exclude=exclude)
    flat_rows = jnp.clip(cu_seqlens[:, None, None] + idx, 0, T - 1)
    kh = k_flat.transpose(1, 0, 2)                           # [K, T, dh]
    vh = v_flat.transpose(1, 0, 2)
    harange = jnp.arange(K, dtype=jnp.int32)[None, :, None]
    pk = kh[harange, flat_rows]                              # [R, K, retain, dh]
    pv = vh[harange, flat_rows]
    val = jnp.take_along_axis(
        jnp.broadcast_to(valid_sel[:, None, :], (R, K, S_sel)), idx, axis=2)
    excl = jnp.take_along_axis(
        jnp.broadcast_to(exclude[:, None, :], (R, K, S_sel)), idx, axis=2)
    return PackedKV(pk, pv, idx, val & ~excl)
