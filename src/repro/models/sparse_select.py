"""Head-centric vs uniform sparse KV selection (paper §2.4 eq.5, §4.5 eq.6).

This is the algorithmic core of contribution C3. During a Refresh step the
layer scan calls :func:`select_and_pack` with the freshly computed full-seq
K/V and the active-block queries; it returns a *physically dense* packed cache
``[B, K, R, dh]`` (head-major, contiguous — the paper's "Static Allocation and
Contiguous Storage"). The index map is transient: it is used once here and
never stored, so Reuse-phase attention reads the cache sequentially with zero
gathers.

GQA note: selection operates at KV-head granularity. Per-head scores from the
G query heads of a group are max-aggregated onto their KV head, so "head-
centric" means one independent token set per *KV head* (the finest granularity
at which a packed KV layout can differ). With MQA (K=1, gemma-2b) this
degenerates to a single shared set — documented in DESIGN.md §5.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class PackedKV(NamedTuple):
    k: jax.Array        # [B, K, R, dh]  post-RoPE keys, densely packed
    v: jax.Array        # [B, K, R, dh]
    pos: jax.Array      # [B, K, R] int32  original token positions
    valid: jax.Array    # [B, K, R] bool


def head_scores(
    q_block: jax.Array,   # [B, Sb, H, dh] active-block queries
    k_full: jax.Array,    # [B, S, K, dh]  full-sequence keys (post-RoPE)
    kernel_size: int,
    s_chunk: int = 4096,
    valid: jax.Array | None = None,   # [B, S] bool
) -> jax.Array:
    """Per-KV-head importance scores, eq.(6):  S_{h,j} = maxpool_w(Q_b · K_j).

    Returns [B, K, S] float32. The K-axis is processed in ``s_chunk`` tiles
    so the [B, K, G, Sb, S] alignment tensor never materializes (at 32k
    prefill it would be multiple GiB/device).

    ``valid`` masks raw scores to -inf BEFORE the max-pool: invalid rows
    (batch padding in the padded path, the *next request's* tokens in the
    token-packed path) must not leak relevance into valid boundary tokens
    through the pooling window — otherwise a request's retained set would
    depend on what it happens to be batched with.
    """
    B, Sb, H, dh = q_block.shape
    K = k_full.shape[2]
    G = H // K
    qg = q_block.reshape(B, Sb, K, G, dh)

    def tile(kc):  # kc: [B, c, K, dh] -> [B, K, c]
        r = jnp.einsum("bqkgd,bskd->bkgqs", qg, kc).astype(jnp.float32)
        return r.max(axis=(2, 3))

    S = k_full.shape[1]
    if S > s_chunk and S % s_chunk == 0:
        kc = k_full.reshape(B, S // s_chunk, s_chunk, K, dh)
        raw = jax.lax.map(tile, kc.transpose(1, 0, 2, 3, 4))
        raw = raw.transpose(1, 2, 0, 3).reshape(B, K, S)
    else:
        raw = tile(k_full)  # [B, K, S]
    if valid is not None:
        raw = jnp.where(valid[:, None, :], raw, -jnp.inf)
    # local max-pooling with window w (captures neighbourhood relevance)
    w = kernel_size
    if w > 1:
        pads = [raw]
        for off in range(1, w // 2 + 1):
            pads.append(jnp.pad(raw[..., off:], ((0, 0), (0, 0), (0, off)),
                                constant_values=-jnp.inf))
            pads.append(jnp.pad(raw[..., :-off], ((0, 0), (0, 0), (off, 0)),
                                constant_values=-jnp.inf))
        raw = jnp.stack(pads).max(axis=0)
    return raw


def select_indices(
    scores: jax.Array,       # [B, K, S] float32
    retain: int,
    *,
    mode: str,               # "head" (ours) | "uniform" (Sparse-dLLM)
    exclude: jax.Array,      # [B, S] bool — active block / invalid positions
) -> jax.Array:
    """Top-k token indices per KV head. Returns [B, K, R] int32 (sorted)."""
    neg = jnp.float32(-1e30)
    scores = jnp.where(exclude[:, None, :], neg, scores)
    if mode == "uniform":
        # Sparse-dLLM eq.(5): aggregate across heads -> one shared index set
        shared = scores.sum(axis=1, keepdims=True)          # [B, 1, S]
        shared = jnp.broadcast_to(shared, scores.shape)
        scores = shared
    _, idx = jax.lax.top_k(scores, retain)                   # [B, K, R]
    # sort selected indices so the packed cache preserves sequence order
    return jnp.sort(idx, axis=-1).astype(jnp.int32)


def pack(
    idx: jax.Array,        # [B, K, R]
    k_full: jax.Array,     # [B, S, K, dh]
    v_full: jax.Array,     # [B, S, K, dh]
    token_valid: jax.Array,  # [B, S] bool
) -> PackedKV:
    """Gather the retained tokens into the dense head-major layout.

    The single gather here is the *only* indirection in the whole C3 pipeline;
    it runs once per Refresh, after which Reuse reads contiguously.
    """
    kh = k_full.transpose(0, 2, 1, 3)   # [B, K, S, dh]
    vh = v_full.transpose(0, 2, 1, 3)
    pk = jnp.take_along_axis(kh, idx[..., None], axis=2)
    pv = jnp.take_along_axis(vh, idx[..., None], axis=2)
    val = jnp.take_along_axis(
        jnp.broadcast_to(token_valid[:, None, :], idx.shape[:2] + token_valid.shape[1:]),
        idx, axis=2)
    return PackedKV(pk, pv, idx, val)


def select_and_pack(
    q_block: jax.Array,
    k_full: jax.Array,
    v_full: jax.Array,
    *,
    retain: int,
    kernel_size: int,
    mode: str,
    exclude: jax.Array,
    token_valid: jax.Array,
) -> PackedKV:
    if mode == "none":
        # dense retention (r = 1.0): keep everything outside the block, packed
        # to `retain` slots by score so shapes stay static.
        scores = jnp.zeros(k_full.shape[:2], jnp.float32)[:, None, :]
        scores = jnp.broadcast_to(scores, (k_full.shape[0], k_full.shape[2], k_full.shape[1]))
        scores = scores - jnp.arange(k_full.shape[1], dtype=jnp.float32)[None, None, :] * 1e-6
        idx = select_indices(scores, retain, mode="uniform", exclude=exclude)
    else:
        scores = head_scores(q_block, k_full, kernel_size, valid=token_valid)
        idx = select_indices(scores, retain, mode=mode, exclude=exclude)
    packed = pack(idx, k_full, v_full, token_valid)
    # positions excluded (block/invalid) may still be picked when fewer than
    # `retain` candidates exist; mark them invalid so attention masks them.
    excl = jnp.take_along_axis(
        jnp.broadcast_to(exclude[:, None, :], idx.shape[:2] + exclude.shape[1:]),
        idx, axis=2)
    return PackedKV(packed.k, packed.v, packed.pos, packed.valid & ~excl)
