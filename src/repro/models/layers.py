"""Shared layer primitives: RMSNorm, RoPE, GQA attention, gated MLPs, softcap.

Conventions
-----------
* Activations: ``[B, S, D]``; attention heads kept 4-D ``[B, S, H, dh]``.
* GQA: ``H = K * G`` query heads over ``K`` KV heads; scores einsum groups G.
* All softmax/normalization math in float32, cast back to the working dtype.
* Attention is *bidirectional* (diffusion LM). Causal masking is available for
  the SSM/audio-AR paths via ``mask_mode``.
* The full-sequence ("Refresh") path uses query-blocked attention
  (``lax.map`` over query chunks) so the score tensor never exceeds
  ``[B, heads, q_chunk, Sk]`` — the TPU-side analogue of IO-aware tiling,
  and the thing that makes 32k-token refresh steps lowerable at all.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# Query-chunk size for blocked (Refresh-phase) attention.
DEFAULT_Q_CHUNK = 1024

# ---------------------------------------------------------------------------
# Activation-sharding policy. The launch layer installs PartitionSpecs here
# (under an active mesh) and model code pins activations at layer boundaries;
# without a policy (engine/smoke tests on one device) these are no-ops.
# Pinning matters: XLA's SPMD propagation otherwise picks degenerate layouts
# downstream of the vocab-sharded embedding gather (observed: involuntary
# full rematerialization + 49 GiB/device temps on gemma-2b×train_4k).
# ---------------------------------------------------------------------------
_SHARDING_POLICY: dict = {}


def set_sharding_policy(policy: dict) -> None:
    """policy: name -> PartitionSpec, e.g. {"act3d": P(('pod','data'),None,None)}."""
    _SHARDING_POLICY.clear()
    _SHARDING_POLICY.update(policy)


def constrain(x: jax.Array, name: str) -> jax.Array:
    spec = _SHARDING_POLICY.get(name)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + scale) parameterization keeps init at identity
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_tables(positions: jax.Array, head_dim: int, theta: float):
    """cos/sin tables for given integer positions. positions: [...]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., half]
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [B, S, H, dh]; cos/sin: [B, S, half] (or [S, half])."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x32[..., :half], x32[..., half:]
    if cos.ndim == 2:  # [S, half] -> broadcast over batch
        cos = cos[None]
        sin = sin[None]
    cos = cos[..., None, :]  # [B, S, 1, half]
    sin = sin[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention(
    q: jax.Array,              # [B, Sq, H, dh]
    k: jax.Array,              # [B, Sk, K, dh]
    v: jax.Array,              # [B, Sk, K, dh]
    *,
    q_pos: jax.Array,          # [B, Sq]
    kv_pos: jax.Array,         # [B, Sk]
    kv_valid: Optional[jax.Array] = None,  # [B, Sk] bool (padding mask)
    q_seg: Optional[jax.Array] = None,     # [B, Sq] int32 segment (varlen)
    kv_seg: Optional[jax.Array] = None,    # [B, Sk] int32 segment (varlen)
    mask_mode: str = "bidirectional",
    window: int = 0,           # static window size (0 = no local masking)
    is_local: jax.Array | bool = False,    # runtime flag (gemma2 alt layers)
    attn_softcap: float = 0.0,
    q_chunk: int = DEFAULT_Q_CHUNK,
    use_kernel: bool = False,              # Pallas flash-refresh kernel
) -> jax.Array:
    """Query-blocked exact attention. Returns [B, Sq, H, dh].

    Masks are built *per query chunk* ([B, c, Sk] bool) — never a full
    [B, Sq, Sk] bias — which is what keeps 32k/500k refresh steps lowerable.
    ``use_kernel`` dispatches to the flash-refresh Pallas kernel (forward
    only — the serving path; training keeps the differentiable jnp path).
    ``q_seg``/``kv_seg`` restrict attention to same-segment tokens — the
    token-packed (varlen) Refresh path, where one flat stream carries many
    requests (the Pallas varlen kernel is dispatched by the packed layer
    directly; this jnp path is its correctness oracle).
    """
    if use_kernel and q.shape[1] == k.shape[1] and q_seg is None:
        from repro.kernels import ops as kops
        B, Sq = q.shape[:2]
        return kops.flash_refresh_attention(
            q, k, v, q_pos=q_pos, kv_pos=kv_pos,
            kv_valid=(kv_valid if kv_valid is not None
                      else jnp.ones((B, Sq), bool)),
            mask_mode=mask_mode, window=window, is_local=is_local,
            softcap=attn_softcap)
    B, Sq, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    scale = dh ** -0.5
    qg = q.reshape(B, Sq, K, G, dh)
    has_seg = q_seg is not None
    needs_mask = (mask_mode == "causal") or window or \
        (kv_valid is not None) or has_seg
    if not has_seg:
        q_seg = q_pos              # dummy thread-through, never consulted

    def chunk_mask(qp, qs):        # qp/qs: [B, c] -> [B, c, Sk] bool | None
        if not needs_mask:
            return None
        ok = jnp.ones((B, qp.shape[1], kv_pos.shape[1]), bool)
        if kv_valid is not None:
            ok &= kv_valid[:, None, :]
        if has_seg:
            ok &= qs[:, :, None] == kv_seg[:, None, :]
        if mask_mode == "causal":
            ok &= qp[:, :, None] >= kv_pos[:, None, :]
        if window:
            dist = jnp.abs(qp[:, :, None] - kv_pos[:, None, :])
            ok &= jnp.where(is_local, dist <= window, True)
        return ok

    # remat'd: the backward pass recomputes this chunk's [*, c, Sk] scores/
    # probs instead of the q-chunk map stacking them as f32 residuals
    # (without this, train_4k peaks at [nq, B, H, c, S] f32 — 20+ GiB/device).
    @jax.checkpoint
    def block(args):
        qb, qp, qs = args          # qb: [B, c, K, G, dh]; qp/qs: [B, c]
        s = jnp.einsum("bqkgd,bskd->bkgqs", qb, k).astype(jnp.float32) * scale
        if attn_softcap:
            s = attn_softcap * jnp.tanh(s / attn_softcap)
        ok = chunk_mask(qp, qs)
        if ok is not None:
            s = jnp.where(ok[:, None, None, :, :], s, -1e30)  # [B,K,G,c,Sk]
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bkgqs,bskd->bqkgd", p, v)

    if Sq <= q_chunk:
        out = block((qg, q_pos, q_seg))
    else:
        pad = (-Sq) % q_chunk
        qp_pad = qg
        pos_pad = q_pos
        seg_pad = q_seg
        if pad:   # vlm/audio: frontend offsets make Sq non-divisible
            qp_pad = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
            pos_pad = jnp.pad(q_pos, ((0, 0), (0, pad)))
            seg_pad = jnp.pad(q_seg, ((0, 0), (0, pad)))
        Sp = Sq + pad
        nq = Sp // q_chunk
        qc = qp_pad.reshape(B, nq, q_chunk, K, G, dh).transpose(1, 0, 2, 3, 4, 5)
        pc = pos_pad.reshape(B, nq, q_chunk).transpose(1, 0, 2)
        sc = seg_pad.reshape(B, nq, q_chunk).transpose(1, 0, 2)
        out = jax.lax.map(block, (qc, pc, sc))      # [nq, B, c, K, G, dh]
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sp, K, G, dh)[:, :Sq]
    return out.reshape(B, Sq, H, dh)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def gated_mlp(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
              w_down: jax.Array, activation: str) -> jax.Array:
    act = jax.nn.gelu if activation == "gelu" else jax.nn.silu
    g = act(jnp.einsum("bsd,df->bsf", x, w_gate))
    u = jnp.einsum("bsd,df->bsf", x, w_up)
    return jnp.einsum("bsf,fd->bsd", g * u, w_down)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def layer_flags(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer is_local flag for alt_local_global patterns. [L] bool."""
    if cfg.layer_pattern == "alt_local_global":
        # gemma2: even layers local (sliding window), odd layers global
        return jnp.arange(cfg.n_layers) % 2 == 0
    return jnp.zeros((cfg.n_layers,), bool)
