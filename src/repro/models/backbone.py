"""Unified per-family model API used by the engine, trainer, and dry-run.

  * :func:`init_params`   — build the param pytree for any assigned arch.
  * :func:`train_forward` — full-sequence forward for the masked-diffusion
    training loss. Returns (normed hidden, moe aux loss).
  * :func:`serve_refresh` — the paper's **Refresh** phase: full forward,
    capture the serving cache (packed sparse KV / SSM state), return the
    active block's hidden states.
  * :func:`serve_reuse`   — the paper's **Reuse** phase: active-block forward
    over the cached context.

VLM (`internvl2-76b`) and audio (`musicgen-medium`) archs take a stub
frontend: precomputed patch/frame embeddings occupying the first
``frontend_len`` positions (projected by a learned matrix); the LM backbone
is real. Diffusion decoding operates on the text region. On the
token-packed serving path the frontend rows ride as a fixed-length prefix
of each request's segment in the flat stream (:func:`embed_inputs_packed`),
so vlm/audio pack like every other family — no padded-oracle fallback.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import hybrid as HY
from repro.models import layers as L
from repro.models import lm_head as LM
from repro.models import ssm as S
from repro.models import transformer as T
from repro.models.sparse_select import PackedKV

ATTN_FAMILIES = ("dense", "moe", "vlm", "audio")


def mask_mode(cfg: ModelConfig) -> str:
    """Diffusion LMs are bidirectional; SSM-bearing archs are causal."""
    return "causal" if cfg.family in ("ssm", "hybrid") else "bidirectional"


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    k_e, k_s, k_f = jax.random.split(key, 3)
    params = {
        "embed": LM.init_embed(cfg, k_e, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.family in ATTN_FAMILIES:
        params["stack"] = T.init_layer_stack(cfg, k_s, dtype)
    elif cfg.family == "ssm":
        params["stack"] = S.init_ssm_stack(cfg, k_s, dtype)
    elif cfg.family == "hybrid":
        params["stack"] = HY.init_hybrid_params(cfg, k_s, dtype)
    else:
        raise ValueError(cfg.family)
    if cfg.frontend_dim:
        params["frontend"] = {
            "proj": L.dense_init(k_f, (cfg.frontend_dim, cfg.d_model), dtype)}
    return params


def embed_inputs(params: dict, cfg: ModelConfig, tokens: jax.Array,
                 frontend: Optional[jax.Array] = None) -> jax.Array:
    """tokens: [B, S_text]; frontend: [B, F, F_dim] or None -> [B, S, D]."""
    x = LM.embed_tokens(params["embed"], tokens)
    if cfg.frontend_dim:
        assert frontend is not None, f"{cfg.name} needs frontend embeddings"
        fe = jnp.einsum("bfe,ed->bfd", frontend.astype(x.dtype),
                        params["frontend"]["proj"])
        x = jnp.concatenate([fe, x], axis=1)
    return L.constrain(x, "act3d")


def embed_inputs_packed(
    params: dict,
    cfg: ModelConfig,
    flat_tokens: jax.Array,              # [T] int32 packed token stream
    cu_seqlens: jax.Array,               # [R] int32 segment start per request
    seq_lens: jax.Array,                 # [R] int32 true segment length (0=pad)
    frontend: Optional[jax.Array] = None,   # [R, F, F_dim]
) -> jax.Array:
    """Packed-stream counterpart of :func:`embed_inputs` -> [T, D].

    Each request's segment in the flat stream is ``[frontend prefix ; text]``
    (the frontend rows are a FIXED-LENGTH prefix of length
    ``cfg.frontend_len``); the projected frontend embeddings are scattered
    onto the prefix rows at ``cu_seqlens[r] + [0, F)``, overwriting the
    placeholder token embeddings the engine wrote there. Padding requests
    (``seq_lens == 0``) scatter nowhere — their rows are redirected out of
    bounds and dropped, so a bucket-exact stream's real tail rows are never
    clobbered. Text-only archs (``frontend_dim == 0``) reduce to a plain
    embedding lookup."""
    x = LM.embed_tokens(params["embed"], flat_tokens)          # [T, D]
    if cfg.frontend_dim:
        assert frontend is not None, f"{cfg.name} needs frontend embeddings"
        n_rows, D = x.shape
        F = cfg.frontend_len
        fe = jnp.einsum("rfe,ed->rfd", frontend.astype(x.dtype),
                        params["frontend"]["proj"])            # [R, F, D]
        rows = cu_seqlens[:, None] + jnp.arange(F, dtype=jnp.int32)[None]
        rows = jnp.where((seq_lens > 0)[:, None], rows, n_rows)  # pad -> OOB
        x = x.at[rows.reshape(-1)].set(fe.reshape(-1, D), mode="drop")
    return x


def _final(params, cfg, h):
    return L.rms_norm(h, params["final_norm"], cfg.rms_eps)


def _serve_chunk_cfg(cfg: ModelConfig, block_size: int) -> ModelConfig:
    """SSM chunk must divide block boundaries for state capture."""
    if cfg.family in ("ssm", "hybrid"):
        c = math.gcd(cfg.ssm_chunk, block_size)
        if c != cfg.ssm_chunk:
            return dataclasses.replace(cfg, ssm_chunk=c)
    return cfg


# ---------------------------------------------------------------------------
# training forward
# ---------------------------------------------------------------------------

def train_forward(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,
    frontend: Optional[jax.Array] = None,
    *,
    remat: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    x = embed_inputs(params, cfg, tokens, frontend)
    B, Sq, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    aux = jnp.float32(0.0)
    if cfg.family in ATTN_FAMILIES:
        h, _, aux = T.forward_full(
            params["stack"], cfg, x, positions,
            mask_mode=mask_mode(cfg), remat=remat)
    elif cfg.family == "ssm":
        body = lambda c, p: (S.mamba_block(p, c, cfg), None)
        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        h, _ = jax.lax.scan(body, x, params["stack"])
    else:  # hybrid
        h, _ = HY.forward_full(params["stack"], cfg, x, positions, remat=remat)
    return _final(params, cfg, h), aux


# ---------------------------------------------------------------------------
# serving: Refresh
# ---------------------------------------------------------------------------

class RefreshOut(NamedTuple):
    block_hidden: jax.Array      # [B, Sb, D] (final-normed)
    cache: object                # PackedKV | SSMCache | HybridCache


def _slice_block(h: jax.Array, block_start: jax.Array, Sb: int) -> jax.Array:
    return jax.vmap(
        lambda hi, st: jax.lax.dynamic_slice_in_dim(hi, st, Sb, axis=0)
    )(h, block_start)


def serve_refresh(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,               # [B, S_text]
    block_start: jax.Array,          # [B] int32 (position in the FULL sequence)
    serve: T.ServeContext,
    frontend: Optional[jax.Array] = None,
    token_valid: Optional[jax.Array] = None,   # [B, S_total]
) -> RefreshOut:
    x = embed_inputs(params, cfg, tokens, frontend)
    B, Sq, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    if token_valid is None:
        token_valid = jnp.ones((B, Sq), bool)
    if cfg.family in ATTN_FAMILIES:
        h, packed, _ = T.forward_full(
            params["stack"], cfg, x, positions, token_valid=token_valid,
            mask_mode=mask_mode(cfg), serve=serve, block_start=block_start)
        cache = packed
    elif cfg.family == "ssm":
        ccfg = _serve_chunk_cfg(cfg, serve.block_size)

        def body(c, p):
            out, st, hi = S.mamba_block(p, c, ccfg, capture_at=block_start)
            return out, (st, hi)

        h, (st, hi) = jax.lax.scan(body, x, params["stack"])
        cache = S.SSMCache(state=st, conv=hi)
    else:  # hybrid
        ccfg = _serve_chunk_cfg(cfg, serve.block_size)
        h, cache = HY.forward_full(
            params["stack"], ccfg, x, positions, token_valid=token_valid,
            serve=serve, block_start=block_start)
    bh = _slice_block(_final(params, cfg, h), block_start, serve.block_size)
    return RefreshOut(block_hidden=bh, cache=cache)


def serve_refresh_packed(
    params: dict,
    cfg: ModelConfig,
    flat_tokens: jax.Array,      # [T] int32 ragged token-packed stream
    positions: jax.Array,        # [T] int32 position within owning request
    seg_ids: jax.Array,          # [T] int32 ascending request id
    token_valid: jax.Array,      # [T] bool (False on bucket padding)
    cu_seqlens: jax.Array,       # [R] int32 flat start offset per request
    seq_lens: jax.Array,         # [R] int32 true SEGMENT length per request
    block_start: jax.Array,      # [R] int32 block offset within the SEGMENT
    serve: T.ServeContext,
    frontend: Optional[jax.Array] = None,   # [R, F, F_dim] (vlm/audio)
) -> RefreshOut:
    """Token-packed Refresh (§4.1 flattened engine): one flat ``[T, ...]``
    stream replaces the padded ``[B, S]`` batch, so compute scales with real
    tokens. Attention families run the segment-masked varlen attention
    stream; SSM/hybrid families run the segment-reset varlen SSD scan (jnp
    associative-scan fallback or the Pallas ``kernels/ssm_scan`` kernel).
    Modality-frontend archs (vlm/audio) pack too: each request's segment is
    ``[frontend prefix ; text]`` (:func:`embed_inputs_packed` scatters the
    projected frontend rows onto the fixed-length prefix), so ``seq_lens``,
    ``positions``, and ``block_start`` are all expressed over the full
    prefix+text segment and the whole segment attends/selects as one
    sequence — exactly the padded oracle's geometry, minus the rectangle.
    Emits the identical per-request ``RefreshOut`` contract as
    :func:`serve_refresh` (block hidden [R, Sb, D] + per-slot cache), which
    is kept as the correctness oracle for every family on this path."""
    if cfg.frontend_dim:
        # segments are up to frontend_len longer than the text cap: widen
        # the per-request length bound that drives the select/pack gather
        # view and the windowed jnp attention fallback
        serve = dataclasses.replace(
            serve, max_seq_len=serve.max_seq_len + cfg.frontend_len)
    x = embed_inputs_packed(params, cfg, flat_tokens, cu_seqlens, seq_lens,
                            frontend)[None]                   # [1, T, D]
    x = L.constrain(x, "act3d")
    if cfg.family in ATTN_FAMILIES:
        h, cache, _ = T.forward_full_packed(
            params["stack"], cfg, x, positions[None], seg_ids[None],
            token_valid[None], cu_seqlens, seq_lens, block_start, serve)
    elif cfg.family == "ssm":
        ccfg = _serve_chunk_cfg(cfg, serve.block_size)
        use_k = bool(serve.use_flash_refresh or serve.use_flash_kernel)

        def body(c, p):
            out, st, hi = S.mamba_block_packed(
                p, c, ccfg, seg_ids, positions, cu_seqlens, block_start,
                use_kernel=use_k)
            return out, (st, hi)

        h, (st, hi) = jax.lax.scan(body, x, params["stack"])
        cache = S.SSMCache(state=st, conv=hi)
    else:  # hybrid
        ccfg = _serve_chunk_cfg(cfg, serve.block_size)
        h, cache = HY.forward_full_packed(
            params["stack"], ccfg, x, positions[None], seg_ids[None],
            token_valid[None], cu_seqlens, seq_lens, block_start, serve)
    # pin the packed hidden stream at the stage boundary: under a serving
    # mesh GSPMD otherwise inherits the vocab-sharded embedding layout into
    # the [T, D] stream and the select/pack gathers downstream of it
    hn = L.constrain(_final(params, cfg, h)[0], "packed_h")   # [T, D]
    rows = T.packed_block_rows(cu_seqlens, block_start, serve.block_size,
                               hn.shape[0])
    return RefreshOut(block_hidden=hn[rows], cache=cache)


# ---------------------------------------------------------------------------
# serving: Reuse
# ---------------------------------------------------------------------------

def _ssm_reuse(params: dict, cfg: ModelConfig, xb: jax.Array, cache):
    """Reuse-phase SSM decode over the layer stack, shared by the padded and
    packed paths — the recurrence is block-exact per request, so both
    execute the identical scan (only the batch geometry differs)."""
    def body(c, scanned):
        p, st, hi = scanned
        return S.mamba_decode_block(p, c, cfg, st, hi), None
    h, _ = jax.lax.scan(body, xb, (params["stack"], cache.state, cache.conv))
    return h


def serve_reuse_packed(
    params: dict,
    cfg: ModelConfig,
    flat_tokens: jax.Array,      # [Tq] int32 packed active-block stream
    flat_positions: jax.Array,   # [Tq] int32 absolute positions
    cache,                       # PackedKV, leading [L], batch = Tq // Sb
    serve: T.ServeContext,
) -> jax.Array:
    """Token-packed Reuse (whole-iteration packing): the iteration's R active
    blocks run as ONE ragged ``[R·Sb]`` query stream against their gathered
    slot caches (``Tq = R·Sb`` rounded to the token bucket by the engine —
    never a pow2 batch bucket). Attention families run the flat varlen
    cross-attention; SSM blocks decode recurrently from their cached states
    (block-exact — the packed win is the exact request count); hybrids
    combine both with a causal shared block. Modality-frontend archs take
    this path unchanged: the active block is always text, so the Reuse
    stream is text-only by construction — the frontend prefix participates
    only through whatever rows Refresh retained into the gathered cache
    (and through the absolute ``flat_positions``, which are offset by
    ``frontend_len``). Emits the flat ``[Tq, D]`` final-normed hidden
    stream the packed logit stage consumes directly; the padded
    :func:`serve_reuse` is kept as the correctness oracle for every family,
    same policy as Refresh."""
    Sb = serve.block_size
    Tq = flat_tokens.shape[0]
    R = Tq // Sb
    xb = LM.embed_tokens(params["embed"], flat_tokens.reshape(R, Sb))
    if cfg.family in ATTN_FAMILIES:
        h = T.forward_block_packed(params["stack"], cfg, xb,
                                   flat_positions.reshape(R, Sb), cache,
                                   serve=serve)
    elif cfg.family == "ssm":
        h = _ssm_reuse(params, cfg, xb, cache)
    else:  # hybrid
        h = HY.forward_block_packed(params["stack"], cfg, xb,
                                    flat_positions.reshape(R, Sb), cache,
                                    serve=serve)
    # same boundary pin as the packed Refresh stream: the flat hidden rows
    # feed the (vocab-parallel) logit stage replicated over the mesh
    return L.constrain(_final(params, cfg, h).reshape(Tq, -1), "packed_h")


def serve_reuse(
    params: dict,
    cfg: ModelConfig,
    block_tokens: jax.Array,     # [B, Sb]
    block_positions: jax.Array,  # [B, Sb] absolute positions
    cache,
    serve: T.ServeContext,
) -> jax.Array:
    xb = LM.embed_tokens(params["embed"], block_tokens)
    if cfg.family in ATTN_FAMILIES:
        h = T.forward_block(params["stack"], cfg, xb, block_positions, cache,
                            serve=serve, mask_mode=mask_mode(cfg))
    elif cfg.family == "ssm":
        h = _ssm_reuse(params, cfg, xb, cache)
    else:  # hybrid
        h = HY.forward_block(params["stack"], cfg, xb, block_positions, cache,
                             serve=serve)
    return _final(params, cfg, h)
