# Subpackage for model definitions. Import submodules explicitly, e.g.
# ``from repro.models import backbone`` — kept lazy to avoid import cycles.
