"""Zamba2-style hybrid backbone: Mamba2 stack + one *shared* attention block.

``n_layers`` Mamba2 layers are organized into groups of
``shared_attn_interval``; after each group the single weight-tied attention+
MLP block runs (Zamba2's global shared transformer block). Remaining layers
form a tail. The model is causal (the Mamba stack forces causality), so
diffusion serving runs in block-causal mode.

Serving caches (per paper phase split):
  * per-Mamba-layer recurrent state + conv history at ``block_start``
    (constant-size — C3 inapplicable to these, see DESIGN.md §5),
  * per-shared-invocation head-centric packed KV (C3 applies here).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T
from repro.models.sparse_select import PackedKV


class HybridCache(NamedTuple):
    ssm_state: jax.Array   # [Lm, B, H, P, N]
    conv: jax.Array        # [Lm, B, ck-1, ch]
    kv: PackedKV           # leading [n_invocations] axis


def group_shape(cfg: ModelConfig) -> Tuple[int, int, int]:
    itv = cfg.shared_attn_interval
    n_groups = cfg.n_layers // itv
    tail = cfg.n_layers - n_groups * itv
    return n_groups, itv, tail


def init_hybrid_params(cfg: ModelConfig, key: jax.Array, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    mamba = S.init_ssm_stack(cfg, k1, dtype)
    # one shared transformer layer (unstacked): reuse the dense layer init
    import dataclasses
    one = dataclasses.replace(cfg, n_layers=1, n_experts=0, family="dense")
    shared = jax.tree.map(lambda a: a[0], T.init_layer_stack(one, k2, dtype))
    return {"mamba": mamba, "shared": shared}


def _split_groups(stack: dict, cfg: ModelConfig):
    n_groups, itv, tail = group_shape(cfg)
    grouped = jax.tree.map(
        lambda a: a[: n_groups * itv].reshape((n_groups, itv) + a.shape[1:]), stack)
    tail_p = jax.tree.map(lambda a: a[n_groups * itv:], stack)
    return grouped, tail_p


def forward_full(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,              # [B, S, D]
    positions: jax.Array,      # [B, S]
    *,
    token_valid: Optional[jax.Array] = None,
    serve: Optional[T.ServeContext] = None,
    block_start: Optional[jax.Array] = None,
    remat: bool = False,
) -> Tuple[jax.Array, Optional[HybridCache]]:
    B, Sq, D = x.shape
    if token_valid is None:
        token_valid = jnp.ones((B, Sq), bool)
    cos, sin = L.rope_tables(positions, cfg.resolved_head_dim, cfg.rope_theta)
    grouped, tail_p = _split_groups(params["mamba"], cfg)
    n_groups, itv, tail = group_shape(cfg)
    capture = block_start if serve is not None else None
    not_local = jnp.asarray(False)

    def mamba_body(carry, p):
        if capture is not None:
            out, st, hi = S.mamba_block(p, carry, cfg, capture_at=capture)
            return out, (st, hi)
        return S.mamba_block(p, carry, cfg), None

    if remat:
        mamba_body = jax.checkpoint(mamba_body, prevent_cse=False)

    def group_body(carry, pg):
        h, ys = jax.lax.scan(mamba_body, carry, pg)
        h, packed, _aux = T._layer_full(
            params["shared"], h, cfg, positions, cos, sin, not_local,
            token_valid, "causal", serve, capture)
        return h, (ys, packed)

    if remat:
        group_body = jax.checkpoint(group_body, prevent_cse=False)

    x, (g_ys, packed) = jax.lax.scan(group_body, x, grouped)
    t_ys = None
    if tail:
        x, t_ys = jax.lax.scan(mamba_body, x, tail_p)

    if serve is None:
        return x, None

    states = jax.tree.map(
        lambda a: a.reshape((n_groups * itv,) + a.shape[2:]), g_ys)
    if tail:
        states = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], 0), states, t_ys)
    cache = HybridCache(ssm_state=states[0], conv=states[1], kv=packed)
    return x, cache


def forward_full_packed(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,               # [1, T, D] embedded packed stream
    positions: jax.Array,       # [1, T] int32 (position within owning request)
    seg_ids: jax.Array,         # [1, T] int32 ascending request id
    token_valid: jax.Array,     # [1, T] bool
    cu_seqlens: jax.Array,      # [R] int32 flat start offset per request
    seq_lens: jax.Array,        # [R] int32 true length per request
    block_start: jax.Array,     # [R] int32 block offset within the request
    serve: T.ServeContext,
) -> Tuple[jax.Array, HybridCache]:
    """Token-packed hybrid Refresh (§4.1 flattened engine, scan families).

    One ragged ``[T, ...]`` stream replaces the padded ``[B, S]`` batch for
    BOTH sublayer kinds: the Mamba2 layers run the segment-reset varlen SSD
    scan (state zeroed at each request boundary, per-request state/conv
    capture in-stream) and the shared attention block runs the causal
    segment-masked varlen path with in-place select/pack. Emits the same
    (hidden [1, T, D], :class:`HybridCache`) contract as the padded
    :func:`forward_full` oracle."""
    _, T_len, _ = x.shape
    cos, sin = L.rope_tables(positions, cfg.resolved_head_dim, cfg.rope_theta)
    grouped, tail_p = _split_groups(params["mamba"], cfg)
    n_groups, itv, tail = group_shape(cfg)
    not_local = jnp.asarray(False)
    use_k = bool(serve.use_flash_refresh or serve.use_flash_kernel)
    geom = T.packed_refresh_geometry(cu_seqlens, seq_lens, block_start,
                                     T_len, serve)

    def mamba_body(carry, p):
        out, st, hi = S.mamba_block_packed(
            p, carry, cfg, seg_ids[0], positions[0], cu_seqlens, block_start,
            use_kernel=use_k)
        return out, (st, hi)

    def group_body(carry, pg):
        h, ys = jax.lax.scan(mamba_body, carry, pg)
        h, packed, _aux = T._layer_full_packed(
            params["shared"], h, cfg, positions, seg_ids, token_valid,
            cos, sin, not_local, serve, cu_seqlens, *geom,
            mask_mode="causal")
        return h, (ys, packed)

    x, (g_ys, packed) = jax.lax.scan(group_body, x, grouped)
    t_ys = None
    if tail:
        x, t_ys = jax.lax.scan(mamba_body, x, tail_p)

    states = jax.tree.map(
        lambda a: a.reshape((n_groups * itv,) + a.shape[2:]), g_ys)
    if tail:
        states = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], 0), states, t_ys)
    return x, HybridCache(ssm_state=states[0], conv=states[1], kv=packed)


def _block_scan(params: dict, cfg: ModelConfig, xb: jax.Array,
                cache: HybridCache, attn) -> jax.Array:
    """Shared Reuse-phase scaffolding for the padded and packed paths: the
    grouped Mamba decode + shared attention/MLP scan over the cache layout,
    with the attention sublayer injected (``attn(h, ck, cv, cpos, cval)``)
    — the single place the group/tail structure lives, so the two paths
    cannot drift."""
    n_groups, itv, tail = group_shape(cfg)
    grouped, tail_p = _split_groups(params["mamba"], cfg)
    st = cache.ssm_state[: n_groups * itv].reshape(
        (n_groups, itv) + cache.ssm_state.shape[1:])
    cv = cache.conv[: n_groups * itv].reshape(
        (n_groups, itv) + cache.conv.shape[1:])

    def mamba_body(carry, scanned):
        p, state, hist = scanned
        return S.mamba_decode_block(p, carry, cfg, state, hist), None

    def group_body(carry, scanned):
        pg, stg, cvg, ck, cvv, cpos, cval = scanned
        h, _ = jax.lax.scan(mamba_body, carry, (pg, stg, cvg))
        h = attn(h, ck, cvv, cpos, cval)
        h2 = L.rms_norm(h, params["shared"]["mlp_norm"], cfg.rms_eps)
        y, _ = T._mlp(params["shared"], h2, cfg)
        return h + y, None

    kv = cache.kv
    xb, _ = jax.lax.scan(
        group_body, xb, (grouped, st, cv, kv.k, kv.v, kv.pos, kv.valid))
    if tail:
        t_st = cache.ssm_state[n_groups * itv:]
        t_cv = cache.conv[n_groups * itv:]
        xb, _ = jax.lax.scan(mamba_body, xb, (tail_p, t_st, t_cv))
    return xb


def forward_block(
    params: dict,
    cfg: ModelConfig,
    xb: jax.Array,              # [B, Sb, D]
    block_positions: jax.Array,
    cache: HybridCache,
    *,
    serve: T.ServeContext,
) -> jax.Array:
    cos, sin = L.rope_tables(block_positions, cfg.resolved_head_dim,
                             cfg.rope_theta)
    not_local = jnp.asarray(False)

    def attn(h, ck, cvv, cpos, cval):
        return T.reuse_attention_layer(
            params["shared"], h, cfg, cos, sin, block_positions, not_local,
            ck, cvv, cpos, cval, "causal", use_kernel=serve.use_flash_kernel,
            concat=serve.reuse_concat)

    return _block_scan(params, cfg, xb, cache, attn)


def forward_block_packed(
    params: dict,
    cfg: ModelConfig,
    xb: jax.Array,              # [R, Sb, D] the iteration's active blocks
    block_positions: jax.Array,  # [R, Sb] absolute positions
    cache: HybridCache,          # gathered slot caches, batch axis = R
    *,
    serve: T.ServeContext,
) -> jax.Array:
    """Token-packed hybrid Reuse (whole-iteration packing).

    The Mamba2 decode recurrence is already block-exact per request (no
    raggedness inside a ``Sb``-token block), so the packed win is the batch
    geometry: R runs exactly as scheduled (token-bucket granular — never a
    pow2 request bucket) and, under ``use_flash_kernel``, the shared
    attention block launches ONE flat causal cross-attention dispatch over
    the ``[R·Sb]`` query stream with non-owned KV tiles skipped in-kernel —
    the same contract as the attention families' packed Reuse."""
    cos, sin = L.rope_tables(block_positions, cfg.resolved_head_dim,
                             cfg.rope_theta)
    R, Sb, _ = xb.shape
    not_local = jnp.asarray(False)
    Cr = cache.kv.k.shape[3]
    q_seg = jnp.repeat(jnp.arange(R, dtype=jnp.int32), Sb)
    kv_seg = jnp.repeat(jnp.arange(R, dtype=jnp.int32), Cr + Sb)

    def attn(h, ck, cvv, cpos, cval):
        if serve.use_flash_kernel:
            return T._reuse_attention_layer_flat(
                params["shared"], h, cfg, cos, sin, block_positions,
                not_local, ck, cvv, cpos, cval, q_seg, kv_seg,
                mask_mode="causal")
        return T.reuse_attention_layer(
            params["shared"], h, cfg, cos, sin, block_positions, not_local,
            ck, cvv, cpos, cval, "causal", concat=serve.reuse_concat)

    return _block_scan(params, cfg, xb, cache, attn)
