"""Embedding + output head with Logit-Aware Activation Budgeting (paper C1).

The paper's §3.2 "logit memory boom": a monolithic ``[B, L, V]`` logit tensor
(8.3 GB for LLaDA-8B at B=16, L=2048) sets peak activation memory. dLLM-Serve
bounds it by splitting the output projection into serial token-axis
sub-batches of ``max_num_logits`` tokens (§4.3). On TPU we go one step
further: within a sub-batch the *fused* path (``repro.kernels``) tiles the
vocab axis through VMEM with an online argmax/logsumexp, so peak activation is
``[chunk, V_tile]`` — the full ``[N, V]`` never exists even transiently.

Three decode modes (``ServeConfig.logit_mode``):
  * ``monolithic`` — materialize ``[N, V]`` (the baseline the paper attacks),
  * ``chunked``    — paper-faithful serial sub-batches (jnp),
  * ``fused``      — sub-batches + Pallas online-argmax kernel (ours).

The same decomposition is applied to the *training* loss: the chunked
masked-diffusion CE never materializes more than ``[chunk, V]`` logits.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def init_embed(cfg: ModelConfig, key: jax.Array, dtype) -> dict:
    p = {"table": L.dense_init(key, (cfg.vocab_size, cfg.d_model), dtype)}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["lm_head"] = L.dense_init(k2, (cfg.d_model, cfg.vocab_size), dtype)
    return p


def embed_tokens(params: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def _logits(params: dict, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    """h: [..., D] -> [..., V] (float32, softcapped).

    The optional "logit_w*" sharding-policy constraints (installed by the
    launch layer) pin the head weight to a pure vocab-parallel layout at the
    point of use: the FSDP'd D axis is all-gathered ONCE (hoisted out of the
    chunk scan) instead of the matmul emitting a partial-product
    [chunk, V]-sized all-reduce per chunk — the §Perf "CE reshard" iteration.
    """
    if cfg.tie_embeddings:
        w = L.constrain(params["table"], "logit_w_tied")
        z = jnp.einsum("...d,vd->...v", h, w)
    else:
        w = L.constrain(params["lm_head"], "logit_w")
        z = jnp.einsum("...d,dv->...v", h, w)
    z = z.astype(jnp.float32)
    if cfg.final_softcap:
        z = cfg.final_softcap * jnp.tanh(z / cfg.final_softcap)
    return z


def logits_monolithic(params, cfg, h):
    """The un-budgeted baseline: full [N, V] materialization."""
    return _logits(params, cfg, h)


def _decode_chunk_jnp(params, cfg, h_chunk) -> Tuple[jax.Array, jax.Array]:
    z = _logits(params, cfg, h_chunk)                  # [c, V] f32
    ids = jnp.argmax(z, axis=-1).astype(jnp.int32)
    lse = jax.nn.logsumexp(z, axis=-1)
    conf = jnp.exp(jnp.max(z, axis=-1) - lse)          # prob of argmax
    return ids, conf


def decode_tokens(
    params: dict,
    cfg: ModelConfig,
    h: jax.Array,              # [N, D] hidden states needing logits
    *,
    max_num_logits: int,
    mode: str = "chunked",     # monolithic | chunked | fused
    vocab_tile: int = 1024,
) -> Tuple[jax.Array, jax.Array]:
    """ArgMax decode + confidence under the C1 budget. Returns ([N], [N])."""
    N = h.shape[0]
    if mode == "monolithic" or N <= max_num_logits and mode != "fused":
        return _decode_chunk_jnp(params, cfg, h)

    chunk = min(max_num_logits, N)
    pad = (-N) % chunk
    hp = jnp.pad(h, ((0, pad), (0, 0)))
    hc = hp.reshape(-1, chunk, h.shape[1])

    if mode == "fused":
        from repro.kernels import ops as kops
        if cfg.tie_embeddings:
            w, layout = params["table"], "vd"      # [V, D], no transpose
        else:
            w, layout = params["lm_head"], "dv"    # [D, V]
        fn = lambda hb: kops.fused_logit_argmax(
            hb, w, softcap=cfg.final_softcap, vocab_tile=vocab_tile,
            w_layout=layout)
    else:
        fn = lambda hb: _decode_chunk_jnp(params, cfg, hb)

    ids, conf = jax.lax.map(fn, hc)
    return ids.reshape(-1)[:N], conf.reshape(-1)[:N]


def decode_tokens_packed(
    params: dict,
    cfg: ModelConfig,
    h: jax.Array,              # [N_exec, D] token-bucketed hidden stream
    valid: jax.Array,          # [N_exec] bool (False on bucket padding)
    *,
    max_num_logits: int,
    mode: str = "chunked",     # monolithic | chunked | fused
    vocab_tile: int = 1024,
) -> Tuple[jax.Array, jax.Array]:
    """ArgMax decode over the whole-iteration packed hidden stream.

    The engine hands the real ``N`` block-hidden rows rounded up to the
    ``token_bucket`` granularity (never a pow2 bucket) plus a validity mask.
    C1 chunking applies unchanged, but all-padding chunks short-circuit: the
    fused kernel skips their vocab loop in-kernel and the chunked-jnp path
    branches around the matmul — a packed engine never pays for logits of
    tokens that do not exist. Invalid rows return (id 0, conf 0.0).
    Returns ([N_exec], [N_exec])."""
    N = h.shape[0]
    if mode == "monolithic":
        ids, conf = _decode_chunk_jnp(params, cfg, h)
        return jnp.where(valid, ids, 0), jnp.where(valid, conf, 0.0)

    chunk = min(max_num_logits, N)
    pad = (-N) % chunk
    hc = jnp.pad(h, ((0, pad), (0, 0))).reshape(-1, chunk, h.shape[1])
    vc = jnp.pad(valid, (0, pad)).reshape(-1, chunk)

    if mode == "fused":
        from repro.kernels import ops as kops
        if cfg.tie_embeddings:
            w, layout = params["table"], "vd"      # [V, D], no transpose
        else:
            w, layout = params["lm_head"], "dv"    # [D, V]

        def fn(args):
            hb, vb = args
            return kops.fused_logit_argmax(
                hb, w, softcap=cfg.final_softcap, vocab_tile=vocab_tile,
                w_layout=layout, valid=vb)
    else:
        def fn(args):
            hb, vb = args
            live = lambda _: _decode_chunk_jnp(params, cfg, hb)
            dead = lambda _: (jnp.zeros((hb.shape[0],), jnp.int32),
                              jnp.zeros((hb.shape[0],), jnp.float32))
            ids, conf = jax.lax.cond(vb.any(), live, dead, None)
            return jnp.where(vb, ids, 0), jnp.where(vb, conf, 0.0)

    ids, conf = jax.lax.map(fn, (hc, vc))
    return ids.reshape(-1)[:N], conf.reshape(-1)[:N]


def diffusion_loss(
    params: dict,
    cfg: ModelConfig,
    h: jax.Array,          # [B, S, D]
    labels: jax.Array,     # [B, S] int32
    weights: jax.Array,    # [B, S] float (1.0 on masked/supervised positions)
    *,
    chunk: int = 2048,
) -> jax.Array:
    """Masked-diffusion CE, token-axis chunked (C1 applied to training).

    Never materializes more than [chunk, V] logits; with the vocab axis
    sharded over 'model' this lowers to a local matmul + reduce-scatter.
    """
    B, S, D = h.shape
    hf = h.reshape(B * S, D)
    lf = labels.reshape(-1)
    wf = weights.reshape(-1).astype(jnp.float32)
    N = B * S
    chunk = min(chunk, N)
    pad = (-N) % chunk
    if pad:
        hf = jnp.pad(hf, ((0, pad), (0, 0)))
        lf = jnp.pad(lf, (0, pad))
        wf = jnp.pad(wf, (0, pad))
    nch = hf.shape[0] // chunk

    # Hoist the head-weight layout constraint OUT of the (remat'd) scan —
    # inside it, the FSDP all-gather would re-run fwd+recompute+bwd per
    # chunk (measured: +54 GiB/device of all-gather on gemma-2b×train_4k).
    params = dict(params)
    if cfg.tie_embeddings:
        params["table"] = L.constrain(params["table"], "logit_w_tied")
    elif "lm_head" in params:
        params["lm_head"] = L.constrain(params["lm_head"], "logit_w")

    # Stride-chunk the token axis: chunk b takes tokens {a·nch + b}, so every
    # chunk spans all data shards (contiguous chunking would place each whole
    # chunk on one shard and serialize the scan; CE is token-permutation
    # invariant so this is free).
    if nch > 1:
        hf = hf.reshape(chunk, nch, D).transpose(1, 0, 2)
        lf = lf.reshape(chunk, nch).T
        wf = wf.reshape(chunk, nch).T
        hf = L.constrain(hf, "loss_h3")
        xs = (hf, lf, wf)
    else:
        xs = (hf.reshape(nch, chunk, D), lf.reshape(nch, chunk),
              wf.reshape(nch, chunk))

    @jax.checkpoint
    def body(carry, xs):
        # remat'd: backward recomputes the [chunk, V] logits instead of the
        # scan saving them as residuals — without this the residual stack
        # would reconstitute the full [T, V] tensor and defeat C1.
        hc, lc, wc = xs
        z = _logits(params, cfg, hc)                    # [chunk, V] f32
        lse = jax.nn.logsumexp(z, axis=-1)
        gold = jnp.take_along_axis(z, lc[:, None].astype(jnp.int32), axis=-1)[:, 0]
        nll = (lse - gold) * wc
        return (carry[0] + nll.sum(), carry[1] + wc.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), xs)
    return tot / jnp.maximum(cnt, 1.0)
