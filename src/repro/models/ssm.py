"""Mamba2 — SSD (state-space duality) layers, chunked scan + recurrent decode.

Implements the discrete SSD forward of the Mamba2 paper (arXiv:2405.21060):
intra-chunk quadratic term + inter-chunk state recurrence, all in einsums so
XLA/TPU lowers to MXU matmuls. Serving splits into:

* ``ssm_prefix_state`` — consume a prefix, return the recurrent state at its
  end (the Refresh-phase "cache": constant size, the SSM analogue of KV).
* ``ssm_decode_block`` — recurrently process the active block from a cached
  state (the Reuse phase). O(block) per denoising step, O(1) in context len —
  this is what makes the long_500k cell trivially sub-quadratic for SSM archs.

Token-packed serving (§4.1 flattened engine) adds the varlen counterparts:
``mamba_block_packed`` runs one ragged ``[T_total]`` stream carrying every
Refresh request of an iteration — the causal conv and the SSD recurrence
reset at segment boundaries (``_causal_conv_packed`` / ``varlen_ssd_scan``
jnp fallback / the Pallas ``kernels/ssm_scan`` segment-scan kernel) and the
serving cache is captured per request in-stream, so scan-family compute
scales with real tokens instead of the padded ``[B, max_seq_len]``
rectangle. The padded ``mamba_block`` path is the correctness oracle.

The paper's head-centric sparse KV (C3) is inapplicable here (no KV to
sparsify) — see DESIGN.md §5; C1 (logit budgeting) and C2 (phase scheduling)
still apply unchanged.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


class SSMCache(NamedTuple):
    state: jax.Array     # [Lm, B, H, P, N]
    conv: jax.Array      # [Lm, B, ck-1, conv_ch]


def conv_channels(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def init_ssm_stack(cfg: ModelConfig, key: jax.Array, dtype, n_layers=None) -> dict:
    nl = cfg.n_layers if n_layers is None else n_layers
    D, Din = cfg.d_model, cfg.d_inner
    G, N, Hs = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    ch = conv_channels(cfg)
    ks = jax.random.split(key, 8)
    return {
        "norm": jnp.zeros((nl, D), dtype),
        "w_z": L.dense_init(ks[0], (nl, D, Din), dtype),
        "w_xbc": L.dense_init(ks[1], (nl, D, ch), dtype),
        "w_dt": L.dense_init(ks[2], (nl, D, Hs), dtype),
        "dt_bias": jnp.zeros((nl, Hs), dtype),
        "conv_w": L.dense_init(ks[3], (nl, cfg.ssm_conv_kernel, ch), dtype, scale=0.2),
        "conv_b": jnp.zeros((nl, ch), dtype),
        "A_log": jnp.zeros((nl, Hs), dtype),          # A = -exp(A_log) = -1 at init
        "D_skip": jnp.ones((nl, Hs), dtype),
        "gate_norm": jnp.zeros((nl, Din), dtype),
        "out_proj": L.dense_init(ks[4], (nl, Din, D), dtype),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """x: [..., T] -> [..., T, T]; out[i,j] = sum_{j < m <= i} x[m], -inf above diag."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_scan(
    x: jax.Array,      # [B, S, H, P]
    dt: jax.Array,     # [B, S, H]  (post-softplus, > 0)
    A: jax.Array,      # [H]        (negative)
    Bm: jax.Array,     # [B, S, N]  (G=1 squeezed)
    Cm: jax.Array,     # [B, S, N]
    chunk: int,
    init_state=None,   # [B, H, P, N] | None
    return_chunk_states: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y [B,S,H,P], final_state [B,H,P,N]).

    With ``return_chunk_states`` the second element is instead
    ``states_in [B, nc, H, P, N]`` — the state *entering* each chunk, which
    serving uses to read off the recurrent state at a block boundary.
    """
    Bb, S, H, Pd = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    f32 = jnp.float32

    xc = x.reshape(Bb, nc, chunk, H, Pd)
    dtc = dt.reshape(Bb, nc, chunk, H).astype(f32)
    Bc = Bm.reshape(Bb, nc, chunk, N).astype(f32)
    Cc = Cm.reshape(Bb, nc, chunk, N).astype(f32)
    dA = dtc * A.astype(f32)[None, None, None, :]        # [B, nc, l, H]
    dA = dA.transpose(0, 3, 1, 2)                        # [B, H, nc, l]
    dA_cs = jnp.cumsum(dA, axis=-1)

    xdt = xc.astype(f32) * dtc[..., None]                # [B, nc, l, H, P]

    # 1) intra-chunk (diagonal blocks)
    Ldec = jnp.exp(_segsum(dA))                          # [B, H, nc, l, l]
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)       # [B, nc, l, s]
    y_diag = jnp.einsum("bcls,bhcls,bcshp->bclhp",
                        scores, Ldec, xdt)

    # 2) per-chunk end states
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)      # [B, H, nc, l]
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, xdt)

    # 3) inter-chunk recurrence (include initial state as chunk -1)
    if init_state is None:
        init_state = jnp.zeros((Bb, H, Pd, N), f32)
    chunk_decay = dA_cs[..., -1]                         # [B, H, nc]
    padded = jnp.pad(chunk_decay, ((0, 0), (0, 0), (1, 0)))
    dec = jnp.exp(_segsum(padded))                       # [B, H, nc+1, nc+1]
    dec = jnp.where(jnp.isfinite(dec), dec, 0.0)
    all_states = jnp.concatenate(
        [init_state.astype(f32)[:, None], states], axis=1)
    # all_states: [B, nc+1, H, P, N]; states entering chunk z:
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", dec, all_states)
    states_in = new_states[:, :-1]                       # [B, nc, H, P, N]
    final_state = new_states[:, -1]                      # [B, H, P, N]

    # 4) state -> output within each chunk
    out_decay = jnp.exp(dA_cs)                           # [B, H, nc, l]
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, states_in, out_decay)

    y = (y_diag + y_off).reshape(Bb, S, H, Pd).astype(x.dtype)
    if return_chunk_states:
        return y, states_in
    return y, final_state.astype(f32)


def varlen_ssd_scan(
    xh: jax.Array,        # [T, H, P] packed stream
    dt: jax.Array,        # [T, H]    (post-softplus, > 0)
    A: jax.Array,         # [H]       (negative)
    Bm: jax.Array,        # [T, N]
    Cm: jax.Array,        # [T, N]
    reset: jax.Array,     # [T] bool  (True on each segment's first token)
    cap_rows: jax.Array,  # [R] int32 (state captured AFTER this row; -1 = 0)
) -> Tuple[jax.Array, jax.Array]:
    """Segment-reset SSD scan over a packed ``[T]`` stream (jnp fallback to
    the Pallas ``kernels/ssm_scan`` kernel).

    The recurrence ``h_t = a_t·h_{t-1} + b_t`` (``a_t = exp(dt_t·A)``,
    ``b_t = dt_t·B_t⊗x_t``) is run as one token-level associative scan with
    ``a_t`` zeroed at segment starts, so requests packed back-to-back in the
    stream cannot leak state into each other — exactly the per-request scan
    the padded oracle runs, keyed by cu_seqlens instead of a batch axis.
    Returns (y [T, H, P], captured states [R, H, P, N] f32). Unlike the
    kernel this fallback materializes per-token states ([T, H, P, N] f32 —
    what lets it capture at arbitrary rows), which is why the kernel is the
    production path.
    """
    f32 = jnp.float32
    dtf = dt.astype(f32)
    a = jnp.where(reset[:, None], 0.0, jnp.exp(dtf * A.astype(f32)[None, :]))
    b = jnp.einsum("th,tn,thp->thpn", dtf, Bm.astype(f32), xh.astype(f32))

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar[..., None, None] + br

    _, h_all = jax.lax.associative_scan(comb, (a, b), axis=0)
    y = jnp.einsum("tn,thpn->thp", Cm.astype(f32), h_all)
    cap = jnp.clip(cap_rows, 0, xh.shape[0] - 1)
    st = jnp.where((cap_rows >= 0)[:, None, None, None], h_all[cap], 0.0)
    return y.astype(xh.dtype), st


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 history: jax.Array | None = None):
    """Depthwise causal conv over [B, S, ch]; w: [k, ch].

    Returns (out [B, S, ch], new_history [B, k-1, ch]).
    """
    k = w.shape[0]
    if history is None:
        history = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    xin = jnp.concatenate([history, xbc], axis=1)
    out = sum(xin[:, i:i + xbc.shape[1], :] * w[i][None, None] for i in range(k))
    out = jax.nn.silu(out + b[None, None])
    return out, xin[:, -(k - 1):, :]


def _project(p, h, cfg: ModelConfig):
    z = jnp.einsum("bsd,de->bse", h, p["w_z"])
    xbc = jnp.einsum("bsd,de->bse", h, p["w_xbc"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", h, p["w_dt"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    return z, xbc, dt


def _split_xbc(xbc, cfg: ModelConfig):
    Din, GN = cfg.d_inner, cfg.ssm_groups * cfg.ssm_state
    xin = xbc[..., :Din]
    Bm = xbc[..., Din:Din + GN]
    Cm = xbc[..., Din + GN:]
    return xin, Bm, Cm


def mamba_block(p, x, cfg: ModelConfig, conv_hist=None, init_state=None,
                return_state: bool = False, capture_at=None):
    """One Mamba2 block (residual included). x: [B, S, D].

    ``capture_at`` ([B] int32 positions, multiples of ``cfg.ssm_chunk``):
    additionally returns the recurrent state and conv history *at* that
    position — the serving cache captured during a Refresh pass.
    """
    x = L.constrain(x, "act3d")
    h = L.rms_norm(x, p["norm"], cfg.rms_eps)
    z, xbc_pre, dt = _project(p, h, cfg)
    xbc, new_hist = _causal_conv(xbc_pre, p["conv_w"], p["conv_b"], conv_hist)
    xin, Bm, Cm = _split_xbc(xbc, cfg)
    Bb, S = x.shape[:2]
    xh = xin.reshape(Bb, S, cfg.ssm_heads, cfg.ssm_head_dim)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    chunk = min(cfg.ssm_chunk, S)
    want_chunks = capture_at is not None
    y, state_out = ssd_scan(xh, dt, A, Bm, Cm, chunk, init_state,
                            return_chunk_states=want_chunks)
    y = y + p["D_skip"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(Bb, S, cfg.d_inner)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                   p["gate_norm"], cfg.rms_eps)
    out = x + jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if capture_at is not None:
        c0 = capture_at // chunk                                  # [B]
        state_at = jax.vmap(lambda s, c: s[c])(state_out, c0)     # [B,H,P,N]
        ck = cfg.ssm_conv_kernel
        padded = jnp.pad(xbc_pre, ((0, 0), (ck - 1, 0), (0, 0)))
        hist_at = jax.vmap(
            lambda xb, st: jax.lax.dynamic_slice_in_dim(xb, st, ck - 1, axis=0)
        )(padded, capture_at)                                      # [B,ck-1,ch]
        return out, state_at, hist_at
    if return_state:
        return out, state_out, new_hist
    return out


def _causal_conv_packed(xbc: jax.Array, w: jax.Array, b: jax.Array,
                        seg: jax.Array):
    """Segment-masked depthwise causal conv over a packed stream.

    xbc: [1, T, ch]; w: [k, ch]; seg: [T] int32 request ids. Taps that would
    reach across a segment boundary contribute zero — every request starts
    from the same empty conv history as the padded per-request path.
    """
    k = w.shape[0]
    T = xbc.shape[1]
    out = xbc * w[k - 1][None, None]
    for i in range(k - 1):
        off = k - 1 - i
        shifted = jnp.pad(xbc, ((0, 0), (off, 0), (0, 0)))[:, :T]
        sseg = jnp.pad(seg, (off, 0), constant_values=-1)[:T]
        ok = (sseg == seg)[None, :, None]
        out = out + jnp.where(ok, shifted, 0.0) * w[i][None, None]
    return jax.nn.silu(out + b[None, None])


def mamba_block_packed(p, x, cfg: ModelConfig, seg_ids, positions,
                       cu_seqlens, block_start, use_kernel: bool = False):
    """One Mamba2 block over a token-packed ``[1, T, D]`` ragged stream.

    The scan-family side of the §4.1 flattened engine: requests are
    delimited by ``seg_ids``/``cu_seqlens`` (``positions`` restart at 0 per
    request), the causal conv and the SSD recurrence both reset at segment
    boundaries, and the serving cache (recurrent state + conv history at the
    request's active block) is captured per request — identical semantics to
    the padded ``mamba_block(capture_at=block_start)`` oracle, including its
    chunk-floor state capture (the state *entering* the ``ssm_chunk`` that
    contains ``block_start``). Returns (out [1, T, D],
    state_at [R, H, P, N] f32, hist_at [R, ck-1, ch]).
    """
    x = L.constrain(x, "act3d")
    h = L.rms_norm(x, p["norm"], cfg.rms_eps)
    z, xbc_pre, dt = _project(p, h, cfg)
    xbc = _causal_conv_packed(xbc_pre, p["conv_w"], p["conv_b"], seg_ids)
    xin, Bm, Cm = _split_xbc(xbc, cfg)
    T = x.shape[1]
    xh = xin[0].reshape(T, cfg.ssm_heads, cfg.ssm_head_dim)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    reset = positions == 0
    chunk = cfg.ssm_chunk
    # oracle capture contract: the state ENTERING the chunk that holds
    # block_start = the state after within-request row c0·chunk − 1
    cap_pos = (block_start // chunk) * chunk
    cap_rows = jnp.where(cap_pos > 0, cu_seqlens + cap_pos - 1, -1)
    if use_kernel:
        from repro.kernels import ops as kops
        y, state_at = kops.ssm_segment_scan(
            xh, dt[0], A, Bm[0], Cm[0], reset, cap_rows)
    else:
        y, state_at = varlen_ssd_scan(
            xh, dt[0], A, Bm[0], Cm[0], reset, cap_rows)
    y = y.astype(x.dtype)
    y = y + p["D_skip"].astype(y.dtype)[None, :, None] * xh
    y = y.reshape(1, T, cfg.d_inner)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                   p["gate_norm"], cfg.rms_eps)
    out = x + jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    # conv history entering the block: the ck-1 pre-conv rows before
    # block_start, zeros where they precede the segment start (the padded
    # path's zero front-padding)
    ck = cfg.ssm_conv_kernel
    back = jnp.arange(-(ck - 1), 0, dtype=jnp.int32)
    idx = block_start[:, None] + back[None]               # within-request
    rows = jnp.clip(cu_seqlens[:, None] + idx, 0, T - 1)
    hist_at = jnp.where((idx >= 0)[..., None], xbc_pre[0][rows], 0)
    return out, state_at, hist_at


def mamba_decode_block(p, xb, cfg: ModelConfig, state, conv_hist):
    """Reuse-phase: process the active block recurrently from a cached state.

    xb: [B, Sb, D]; state: [B, H, P, N]; conv_hist: [B, ck-1, ch].
    The cache is NOT advanced (diffusion re-denoises the same block); the
    caller commits the state via ``ssm_prefix_state`` at the next Refresh.
    """
    h = L.rms_norm(xb, p["norm"], cfg.rms_eps)
    z, xbc, dt = _project(p, h, cfg)
    xbc, _ = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_hist)
    xin, Bm, Cm = _split_xbc(xbc, cfg)
    Bb, Sb = xb.shape[:2]
    xh = xin.reshape(Bb, Sb, cfg.ssm_heads, cfg.ssm_head_dim)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    def step(carry, t):
        x_t, dt_t, B_t, C_t = t          # [B,H,P], [B,H], [B,N], [B,N]
        dA = jnp.exp(dt_t * A[None])     # [B, H]
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt_t, B_t, x_t)
        new = carry * dA[..., None, None] + dBx
        y_t = jnp.einsum("bn,bhpn->bhp", C_t, new)
        return new, y_t

    xs = (xh.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2).astype(jnp.float32),
          Cm.transpose(1, 0, 2).astype(jnp.float32))
    _, ys = jax.lax.scan(step, state.astype(jnp.float32), xs)
    y = ys.transpose(1, 0, 2, 3).astype(xb.dtype)       # [B, Sb, H, P]
    y = y + p["D_skip"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(Bb, Sb, cfg.d_inner)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                   p["gate_norm"], cfg.rms_eps)
    return xb + jnp.einsum("bse,ed->bsd", y, p["out_proj"])
