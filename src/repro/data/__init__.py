# Data substrate: synthetic serving workloads + training pipeline.
