"""Synthetic serving workloads mirroring the paper's three traces (§6.1).

No datasets ship offline, so each workload is a *statistical replica* of the
corresponding benchmark's serving-relevant properties — arrival process,
prompt-length distribution, output length — which are the only properties the
paper's systems experiments consume:

  * **livebench** — coding questions: medium prompts (~300 tok, lognormal),
    fixed 256-token generations, Poisson arrivals.
  * **burst** — BurstGPT trace: ON/OFF bursty arrivals (Markov-modulated
    Poisson), heavy-tailed prompt lengths.
  * **osc**  — OpenAI Summarization Comparison: long prompts (~500 tok),
    256-token summaries, Poisson arrivals.

Lengths are scaled by ``scale`` so the same shapes exercise toy CPU models
(max_seq 128-512) and the full dry-run configs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np


@dataclass(frozen=True)
class TraceRequest:
    arrival: float      # seconds
    prompt_len: int
    gen_len: int
    # absolute completion deadline (inf = none). Traces derive it as
    # ``arrival + deadline_slack`` — a pure function of the arrival, NO rng
    # draw, so enabling deadlines never perturbs the trace's random stream
    # (the determinism tests pin the stream).
    deadline: float = float("inf")


def _poisson_arrivals(n: int, rps: float, rng) -> np.ndarray:
    return np.cumsum(rng.exponential(1.0 / rps, n))


def _burst_arrivals(n: int, rps: float, rng, burst_factor: float = 6.0,
                    p_on: float = 0.3) -> np.ndarray:
    """Markov-modulated Poisson: ON periods at burst_factor×rate."""
    out = []
    t = 0.0
    on = False
    while len(out) < n:
        on = rng.random() < (p_on if not on else 0.7)
        rate = rps * burst_factor if on else rps * 0.4
        k = min(n - len(out), rng.integers(2, 8))
        for _ in range(k):
            t += rng.exponential(1.0 / rate)
            out.append(t)
    return np.asarray(out[:n])


def make_trace(name: str, n: int, rps: float, seed: int = 0,
               scale: float = 1.0,
               deadline_slack: float = float("inf")) -> List[TraceRequest]:
    """``deadline_slack``: seconds after arrival by which each request must
    finish (inf = no deadline). Applied post-hoc to the arrival — identical
    rng stream with or without deadlines."""
    rng = np.random.default_rng(seed)
    if name == "livebench":
        arr = _poisson_arrivals(n, rps, rng)
        plen = np.clip(rng.lognormal(np.log(300), 0.4, n), 50, 900)
        glen = np.full(n, 256)
    elif name == "burst":
        arr = _burst_arrivals(n, rps, rng)
        plen = np.clip((rng.pareto(1.8, n) + 1) * 120, 30, 1500)
        glen = np.full(n, 256)
    elif name == "osc":
        arr = _poisson_arrivals(n, rps, rng)
        plen = np.clip(rng.normal(500, 120, n), 150, 1200)
        glen = np.full(n, 256)
    else:
        raise ValueError(name)
    return [TraceRequest(float(a), max(4, int(p * scale)),
                         max(4, int(g * scale)),
                         deadline=float(a) + deadline_slack)
            for a, p, g in zip(arr, plen, glen)]


def trace_prompts(trace: List[TraceRequest], vocab_size: int,
                  seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed + 1)
    return [rng.integers(0, vocab_size - 1, t.prompt_len).astype(np.int32)
            for t in trace]


WORKLOADS = ("livebench", "burst", "osc")
