"""Synthetic serving workloads mirroring the paper's three traces (§6.1).

No datasets ship offline, so each workload is a *statistical replica* of the
corresponding benchmark's serving-relevant properties — arrival process,
prompt-length distribution, output length — which are the only properties the
paper's systems experiments consume:

  * **livebench** — coding questions: medium prompts (~300 tok, lognormal),
    fixed 256-token generations, Poisson arrivals.
  * **burst** — BurstGPT trace: ON/OFF bursty arrivals (Markov-modulated
    Poisson), heavy-tailed prompt lengths.
  * **osc**  — OpenAI Summarization Comparison: long prompts (~500 tok),
    256-token summaries, Poisson arrivals.

Lengths are scaled by ``scale`` so the same shapes exercise toy CPU models
(max_seq 128-512) and the full dry-run configs.

A fourth trace, **shared-prefix**, models production prompt duplication
(shared system prompts, retry/fan-out storms): requests draw their prompt
verbatim from a small pool of prefixes, so the KV pool's content-addressed
sharing (``docs/memory.md``) dedups their Refresh captures. Prefix
assignment uses a rng stream DERIVED from the seed (``default_rng([seed,
...])``), drawn after the main draws — the three existing workloads' random
streams stay byte-identical (regression-pinned in ``tests/test_workloads.py``
so PR 6's deadline determinism survives).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

# spawn key for every prefix-related derived stream — never the main stream
_PREFIX_STREAM = 0x70726566  # "pref"


@dataclass(frozen=True)
class TraceRequest:
    arrival: float      # seconds
    prompt_len: int
    gen_len: int
    # absolute completion deadline (inf = none). Traces derive it as
    # ``arrival + deadline_slack`` — a pure function of the arrival, NO rng
    # draw, so enabling deadlines never perturbs the trace's random stream
    # (the determinism tests pin the stream).
    deadline: float = float("inf")
    # shared-prefix annotation: which prefix-pool entry the first
    # ``prefix_len`` prompt tokens come from (-1 = unique prompt). Purely
    # descriptive — the engine discovers sharing by content hash, never by
    # reading these fields.
    prefix_id: int = -1
    prefix_len: int = 0


@dataclass(frozen=True)
class PrefixSpec:
    """Shape of the shared-prefix trace's prompt pool.

    With ``tail_len=0`` (default) prompts are drawn VERBATIM from the pool,
    so requests sharing a prefix_id have bit-identical full prompts and the
    slot-granular pool dedups their whole KV. A nonzero tail appends unique
    tokens per request — honest modeling of prefix-plus-question traffic,
    but the current slot-granular pool shares nothing for it (sub-slot
    paged sharing is the ROADMAP follow-up; ``block_chain_key`` is already
    a prefix chain in anticipation)."""
    n_prefixes: int = 4
    prefix_len: int = 64
    tail_len: int = 0


def _poisson_arrivals(n: int, rps: float, rng) -> np.ndarray:
    return np.cumsum(rng.exponential(1.0 / rps, n))


def _burst_arrivals(n: int, rps: float, rng, burst_factor: float = 6.0,
                    p_on: float = 0.3) -> np.ndarray:
    """Markov-modulated Poisson: ON periods at burst_factor×rate."""
    out = []
    t = 0.0
    on = False
    while len(out) < n:
        on = rng.random() < (p_on if not on else 0.7)
        rate = rps * burst_factor if on else rps * 0.4
        k = min(n - len(out), rng.integers(2, 8))
        for _ in range(k):
            t += rng.exponential(1.0 / rate)
            out.append(t)
    return np.asarray(out[:n])


def make_trace(name: str, n: int, rps: float, seed: int = 0,
               scale: float = 1.0,
               deadline_slack: float = float("inf"),
               prefix: Optional[PrefixSpec] = None) -> List[TraceRequest]:
    """``deadline_slack``: seconds after arrival by which each request must
    finish (inf = no deadline). Applied post-hoc to the arrival — identical
    rng stream with or without deadlines. ``prefix`` shapes the
    shared-prefix trace's pool (ignored by the other workloads)."""
    rng = np.random.default_rng(seed)
    if name == "shared-prefix":
        spec = prefix or PrefixSpec()
        arr = _poisson_arrivals(n, rps, rng)
        glen = np.full(n, 256)
        pref = max(4, int(spec.prefix_len * scale))
        tail = max(0, int(spec.tail_len * scale))
        # prefix assignment comes from a stream DERIVED from the seed and
        # drawn after the main draws: the main stream stays byte-identical
        # to a prefix-free trace of the same shape, and the three existing
        # workloads (which never reach this branch) are untouched
        prng = np.random.default_rng([seed, _PREFIX_STREAM])
        ids = prng.integers(0, spec.n_prefixes, n)
        return [TraceRequest(float(a), pref + tail, max(4, int(g * scale)),
                             deadline=float(a) + deadline_slack,
                             prefix_id=int(i), prefix_len=pref)
                for a, g, i in zip(arr, glen, ids)]
    if name == "livebench":
        arr = _poisson_arrivals(n, rps, rng)
        plen = np.clip(rng.lognormal(np.log(300), 0.4, n), 50, 900)
        glen = np.full(n, 256)
    elif name == "burst":
        arr = _burst_arrivals(n, rps, rng)
        plen = np.clip((rng.pareto(1.8, n) + 1) * 120, 30, 1500)
        glen = np.full(n, 256)
    elif name == "osc":
        arr = _poisson_arrivals(n, rps, rng)
        plen = np.clip(rng.normal(500, 120, n), 150, 1200)
        glen = np.full(n, 256)
    else:
        raise ValueError(name)
    return [TraceRequest(float(a), max(4, int(p * scale)),
                         max(4, int(g * scale)),
                         deadline=float(a) + deadline_slack)
            for a, p, g in zip(arr, plen, glen)]


def trace_prompts(trace: List[TraceRequest], vocab_size: int,
                  seed: int = 0) -> List[np.ndarray]:
    """Prompt token arrays for ``trace``. Exactly ONE main-stream draw per
    request regardless of prefix annotations (regression-pinned): prefix-
    bearing requests draw their full prompt like everyone else, then
    overwrite the first ``prefix_len`` tokens from the pool entry — pool
    entries come from per-(id, len) derived streams, so pool content is
    independent of request order."""
    rng = np.random.default_rng(seed + 1)
    pool: Dict[Tuple[int, int], np.ndarray] = {}
    out = []
    for t in trace:
        p = rng.integers(0, vocab_size - 1, t.prompt_len).astype(np.int32)
        if t.prefix_id >= 0 and t.prefix_len > 0:
            key = (t.prefix_id, t.prefix_len)
            if key not in pool:
                kr = np.random.default_rng(
                    [seed + 1, _PREFIX_STREAM, t.prefix_id, t.prefix_len])
                pool[key] = kr.integers(
                    0, vocab_size - 1, t.prefix_len).astype(np.int32)
            k = min(t.prefix_len, t.prompt_len)
            p[:k] = pool[key][:k]
        out.append(p)
    return out


def prefix_share_factor(trace: List[TraceRequest]) -> float:
    """Logical/physical slot ratio the trace admits under whole-slot
    content sharing: requests whose prompt is drawn VERBATIM from the pool
    (prefix covers the full prompt) and that share (prefix_id, prompt_len,
    gen_len) produce bit-identical token arrays — one physical slot backs
    the group. Everything else (unique prompts, partial prefixes) is billed
    one slot each. This is the ``share_factor`` fed to
    ``budgeting.plan_memory`` / ``baselines.size_slots``."""
    groups: Dict[Tuple[int, int, int], int] = {}
    unique = 0
    for t in trace:
        if t.prefix_id >= 0 and t.prefix_len >= t.prompt_len:
            key = (t.prefix_id, t.prompt_len, t.gen_len)
            groups[key] = groups.get(key, 0) + 1
        else:
            unique += 1
    phys = len(groups) + unique
    return len(trace) / phys if phys else 1.0


WORKLOADS = ("livebench", "burst", "osc", "shared-prefix")
