"""Training data pipeline: deterministic synthetic token stream, sharded
placement, background prefetch.

At 1000-node scale the pipeline must be (a) deterministic per step for
replayable restarts — batches are pure functions of (seed, step) so a resumed
run consumes identical data with zero coordination state; (b) placed directly
into the per-device shards — ``shard_batch`` device_puts with the batch
sharding so no host gathers; (c) overlapped — ``Prefetcher`` keeps ``depth``
batches in flight on a background thread.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig


def synthetic_batch(cfg: ModelConfig, global_batch: int, seq_len: int,
                    step: int, seed: int = 0) -> np.ndarray:
    """Deterministic tokens for (seed, step): structured (zipf-ish) stream so
    losses move; restart-replayable by construction."""
    rng = np.random.default_rng((seed << 20) ^ step)
    # zipf-distributed ids resemble natural token frequencies
    ids = rng.zipf(1.3, size=(global_batch, seq_len)).astype(np.int64)
    return np.minimum(ids, cfg.vocab_size - 2).astype(np.int32)


def frontend_batch(cfg: ModelConfig, global_batch: int, step: int,
                   seed: int = 0) -> Optional[np.ndarray]:
    if not cfg.frontend_dim:
        return None
    rng = np.random.default_rng((seed << 21) ^ step)
    return rng.standard_normal(
        (global_batch, cfg.frontend_len, cfg.frontend_dim)).astype(np.float32)


def shard_batch(batch: np.ndarray, sharding) -> jax.Array:
    return jax.device_put(batch, sharding)


class Prefetcher:
    """Background-thread prefetch of data batches."""

    def __init__(self, make: Callable[[int], object], start_step: int,
                 depth: int = 2):
        self._make = make
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        s = self._step
        while not self._stop.is_set():
            try:
                self._q.put(self._make(s), timeout=0.1)
                s += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._t.join(timeout=1.0)
