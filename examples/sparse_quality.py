"""Head-centric vs uniform sparse KV: the paper's Fig.6 mechanism, visible.

    PYTHONPATH=src python examples/sparse_quality.py

Builds a synthetic attention problem where each KV head depends on tokens
salient only to it, then shows the retained-token recovery rate of both
policies across retention ratios — uniform (Sparse-dLLM) collapses at low r,
head-centric (dLLM-Serve) keeps every head's critical context.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.quality import RETENTIONS, head_disjoint_recovery


def main():
    print(f"{'retention':>10s} {'head-centric':>14s} {'uniform':>10s}")
    for r in RETENTIONS:
        rh = head_disjoint_recovery("head", r)
        ru = head_disjoint_recovery("uniform", r)
        bar = "*" * int(rh * 20)
        print(f"{r:10.1f} {rh*100:13.1f}% {ru*100:9.1f}%   {bar}")
    print("\npaper: at r=0.1, head-centric holds 75.1% GSM8K vs 40.0% uniform")


if __name__ == "__main__":
    main()
