"""End-to-end serving driver: continuous batching under a bursty arrival
trace, comparing dLLM-Serve against the three baseline systems.

    PYTHONPATH=src python examples/serve_trace.py [--workload burst] [--n 10]

This is the paper's Fig.3/4 experiment in miniature: same engine, same
workload, four system profiles (Fast-dLLM, dLLM-Cache, Sparse-dLLM, ours).
"""
import argparse

from repro.launch.serve import run_serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="burst",
                    choices=["livebench", "burst", "osc"])
    ap.add_argument("--rps", type=float, default=2.0)
    ap.add_argument("--n", type=int, default=10)
    args = ap.parse_args()

    print(f"workload={args.workload} rps={args.rps} n={args.n}\n")
    rows = []
    for system in ("fast-dllm", "dllm-cache", "sparse-dllm", "dllm-serve"):
        r = run_serve("llada-8b", system, args.workload, args.rps, args.n,
                      time_scale=0.02)
        rows.append(r)
        print(f"{system:12s} tput={r['throughput_tok_s']:8.1f} tok/s  "
              f"avg_lat={r['avg_latency']:7.2f}s  p99={r['p99_latency']:7.2f}s")
    best = max(r["throughput_tok_s"] for r in rows[:-1])
    print(f"\ndLLM-Serve speedup vs best baseline: "
          f"{rows[-1]['throughput_tok_s']/best:.2f}x  (paper: 1.61-1.81x)")


if __name__ == "__main__":
    main()
