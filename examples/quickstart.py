"""Quickstart: serve a small diffusion LM with dLLM-Serve on CPU.

    PYTHONPATH=src python examples/quickstart.py

Submits a handful of prompts, runs the full engine (phase-multiplexed
scheduling + head-centric sparse KV + budgeted logit decode), and prints
per-request outputs and engine statistics.
"""
import numpy as np

from repro.configs import ARCHS, reduced
from repro.configs.base import ServeConfig
from repro.core.engine import Engine

def main():
    cfg = reduced(ARCHS["llada-8b"])          # tiny same-family model
    serve = ServeConfig(
        max_num_batched_tokens=512,           # C2: scheduler token budget
        max_num_logits=64,                    # C1: logit decomposition chunk
        retention_ratio=0.5,                  # C3: head-centric retention
        selection="head", scheduler="phase", logit_mode="fused",
        block_size=8, steps_per_block=8, max_seq_len=128, max_slots=8)
    engine = Engine(cfg, serve, seed=0)

    rng = np.random.default_rng(0)
    requests = []
    for i in range(6):
        prompt = rng.integers(0, cfg.vocab_size - 1, rng.integers(8, 32))
        requests.append(engine.submit(prompt, gen_len=16, arrival=0.0, rid=i))

    stats = engine.run()

    print(f"\nserved {len(requests)} requests in {stats.wall_time:.1f}s "
          f"({stats.throughput:.1f} tok/s)")
    print(f"refresh steps={stats.refresh_steps} reuse steps={stats.reuse_steps} "
          f"peak query tokens={stats.peak_query_tokens}")
    for r in requests:
        print(f"  req {r.rid}: latency={r.latency:.2f}s "
              f"out={r.output_tokens()[:10]}...")


if __name__ == "__main__":
    main()
