"""Train a small masked-diffusion LM with the fault-tolerant loop.

    PYTHONPATH=src python examples/train_small.py --steps 30

Uses the full substrate: masked-diffusion loss with C1-chunked CE, AdamW,
grad accumulation, async checkpointing (resume with the same command after
interrupting). ``--model-scale full-100m`` trains a ~100M-param model —
a few hundred steps reproduce a real (slow on CPU) small-LM run.
"""
import argparse
import os

from repro.configs import ARCHS, reduced
from repro.configs.base import TrainConfig
from repro.data.pipeline import synthetic_batch
from repro.train.train_loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--model-scale", default="tiny",
                    choices=["tiny", "full-100m"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    if args.model_scale == "tiny":
        cfg = reduced(ARCHS["llada-8b"])
        G, S = 8, 64
    else:
        # ~100M params: 12L x 512d x 8H, 16k vocab
        cfg = reduced(ARCHS["llada-8b"], n_layers=12, d_model=512, n_heads=8,
                      n_kv_heads=8, head_dim=64, d_ff=2048, vocab_size=16384)
        G, S = 16, 256

    tc = TrainConfig(microbatches=4, loss_chunk=512, warmup_steps=10)
    os.makedirs(args.ckpt_dir, exist_ok=True)
    tr = Trainer(cfg, tc, args.ckpt_dir, G, S, total_steps=500, ckpt_every=10)
    if tr.start_step:
        print(f"resuming from step {tr.start_step}")
    logs = tr.run(args.steps,
                  lambda s: synthetic_batch(cfg, G, S, s, seed=0),
                  quiet=False)
    print(f"\nloss {logs[0]['loss']:.3f} -> {logs[-1]['loss']:.3f} over "
          f"{len(logs)} steps; {tr.events.checkpoints} checkpoints written")


if __name__ == "__main__":
    main()
