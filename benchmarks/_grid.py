"""Shared serving grid: (system × workload × rps) runs, cached to JSON.

Figures 3/4/5 and Table 4 of the paper all read from the same underlying
sweep, so we run it once. CPU-scale: reduced llada-8b config, scaled trace
lengths; *relative* numbers (ours vs baselines) are the reproduction target —
the paper's own claims are 1.61–1.81× (4090) / 1.60–1.74× (L40S) throughput
and ~4× tail latency.
"""
from __future__ import annotations

import json
import os

from repro.launch.serve import run_serve

CACHE = os.path.join(os.path.dirname(__file__), "..", "results",
                     "serve_grid.json")
SYSTEMS = ("fast-dllm", "dllm-cache", "sparse-dllm", "dllm-serve")
WORKLOADS = ("livebench", "burst", "osc")


def grid(quick: bool = True, refresh: bool = False) -> list:
    os.makedirs(os.path.dirname(CACHE), exist_ok=True)
    if os.path.exists(CACHE) and not refresh:
        with open(CACHE) as f:
            return json.load(f)
    # modeled-clock contention sweep (saturation sits near rps≈6 for the
    # scaled device model; the paper's 0.25-0.5 RPS wall scales likewise)
    rps_points = (2.0, 6.0) if quick else (1.0, 2.0, 4.0, 6.0, 12.0)
    n = 16 if quick else 24
    rows = []
    for wl in WORKLOADS:
        for sys_name in SYSTEMS:
            for rps in rps_points:
                r = run_serve("llada-8b", sys_name, wl, rps, n,
                              max_seq_len=192, block_size=8,
                              steps_per_block=8, max_slots=12,
                              max_num_batched_tokens=768,
                              max_num_logits=96, length_scale=0.12)
                rows.append(r)
                with open(CACHE, "w") as f:
                    json.dump(rows, f, indent=1)
    return rows


def best_baseline(rows, wl, rps, key="throughput_tok_s", hi=True):
    vals = [r[key] for r in rows
            if r["workload"] == wl and r["rps"] == rps
            and r["system"] != "dllm-serve"]
    return (max if hi else min)(vals)


def ours(rows, wl, rps, key="throughput_tok_s"):
    return [r[key] for r in rows
            if r["workload"] == wl and r["rps"] == rps
            and r["system"] == "dllm-serve"][0]
