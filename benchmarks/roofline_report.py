"""Dry-run roofline summary: reads results/dryrun_*.json (produced by
``python -m repro.launch.dryrun --all [--multipod]``) and prints the
per-cell roofline terms — the §Roofline table of EXPERIMENTS.md."""
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def run(quick: bool = True):
    out = []
    for mesh, fname in (("16x16", "dryrun_singlepod.json"),
                        ("2x16x16", "dryrun_multipod.json")):
        path = os.path.join(RESULTS, fname)
        if not os.path.exists(path):
            out.append((f"roofline/{mesh}", 0.0, "missing(run_dryrun_first)"))
            continue
        recs = json.load(open(path))
        n_ok = sum(r.get("ok", False) for r in recs)
        out.append((f"roofline/{mesh}/cells_ok", 0.0, f"{n_ok}/{len(recs)}"))
        if mesh != "16x16":
            continue  # per-assignment, the roofline table is single-pod
        for r in recs:
            if not r.get("ok"):
                continue
            out.append((
                f"roofline/{r['arch']}/{r['shape']}",
                r["step_time"] * 1e6,
                f"bottleneck={r['bottleneck']} mfu={r['mfu']*100:.1f}% "
                f"comp={r['t_compute']*1e3:.1f}ms mem={r['t_memory']*1e3:.1f}ms "
                f"coll={r['t_collective']*1e3:.1f}ms "
                f"useful={r['useful_flops_ratio']*100:.0f}%"))
    return out
