"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--full] [--only name]``
prints ``name,us_per_call,derived`` CSV rows (us_per_call = 0.0 for
pure-derived metrics).

``--record`` additionally calls each module's ``record(quick)`` hook (if
it has one) and writes the returned dict to ``BENCH_<name>.json`` at the
repo root — the committed regression artifact.
"""
import argparse
import json
import os
import sys
import time
import traceback

MODULES = [
    "logit_budget",      # §3.2 logit memory boom (Fig.2 mechanism)
    "footprint",         # Table 1
    "quality",           # Fig. 6
    "throughput",        # Fig. 3 + Table 4
    "packing",           # §4.1 flattened engine: padded vs token-packed
    "latency",           # Fig. 4
    "jitter",            # Fig. 5
    "sensitivity",       # Fig. 7
    "ablation",          # Fig. 8
    "roofline_report",   # §Roofline (from dry-run artifacts)
    "robustness",        # overload + chaos (docs/robustness.md)
    "engine",            # pipelined vs sync serving loop (docs/engine.md)
]

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--record", action="store_true",
                    help="write BENCH_<name>.json for modules with a "
                         "record() hook")
    args = ap.parse_args()
    mods = [args.only] if args.only else MODULES
    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            rows = mod.run(quick=not args.full)
            for n, us, derived in rows:
                print(f"{n},{us:.3f},{derived}")
            if args.record and hasattr(mod, "record"):
                path = os.path.join(ROOT, f"BENCH_{name}.json")
                with open(path, "w") as f:
                    json.dump(mod.record(quick=not args.full), f, indent=2,
                              sort_keys=True)
                print(f"# recorded {path}", file=sys.stderr)
        except Exception:
            traceback.print_exc()
            print(f"{name},0.000,ERROR")
            failures += 1
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
