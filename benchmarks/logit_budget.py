"""Paper §3.2 'logit memory boom': XLA-measured peak temp bytes of the
decode stage under each C1 mode, plus the paper's 8.3 GB arithmetic."""
from repro.configs import ARCHS, get_config, reduced
from repro.configs.base import ServeConfig
from repro.core.budgeting import (logit_activation_bytes, measure_logit_peak)


def run(quick: bool = True):
    out = []
    # the paper's own arithmetic: B=16, L=2048, V=126464, fp16 -> 8.3 GB
    cfg = get_config("llada-8b")
    mono = logit_activation_bytes(cfg, ServeConfig(logit_mode="monolithic"),
                                  16 * 2048) / 2  # fp16 convention
    out.append(("logit_budget/paper_example", 0.0,
                f"{mono/1e9:.2f}GB(paper:8.3GB)"))
    # measured (compile-time exact) on a scaled config
    mcfg = reduced(ARCHS["llada-8b"], vocab_size=32768, d_model=256)
    serve = ServeConfig(max_num_logits=512, vocab_tile=256)
    peaks = measure_logit_peak(mcfg, serve, n_tokens=8192)
    for mode, b in peaks.items():
        out.append((f"logit_budget/measured_temp/{mode}", 0.0,
                    f"{b/2**20:.2f}MiB"))
    out.append(("logit_budget/reduction", 0.0,
                f"{peaks['monolithic']/max(peaks['fused'],1):.1f}x_fused "
                f"{peaks['monolithic']/max(peaks['chunked'],1):.1f}x_chunked"))
    return out
