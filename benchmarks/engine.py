"""Engine-loop benchmark: pipelined dispatch-ahead vs the synchronous oracle.

Same Burst trace, same seed, two loops (docs/engine.md):

* ``sync`` — ``pipeline=False``: plan, fill, dispatch, block on the
  device_get, commit — one full host/device round trip per iteration.
* ``pipelined`` — ``pipeline=True``: iteration i+1's plan/layout is built
  while iteration i is still in flight; ONE deferred device_get per
  iteration lands the previous results.

Token output is bit-identical by construction (the bit-identity suite,
tests/test_engine_pipeline.py, asserts ids + stats + caches exact), so the
rows here are purely about the loop's host economics: per-iteration step
time, how much host work was hidden (``overlap_frac`` — structural, 0 for
sync by definition), and wall-clock vs modeled throughput.

``record(quick)`` returns the dict committed as ``BENCH_engine.json`` by
``benchmarks.run --record`` (auto-diffed by diff_bench's BENCH_* glob).
"""
from repro.launch.serve import run_serve


def _serve(pipeline: bool, quick: bool = True, clock: str = "wall") -> dict:
    # size_by_profiler=False pins max_slots so the artifact is stable
    # across profiler changes; burst gives the scheduler enough concurrent
    # residents that plan/fill host work is non-trivial per iteration.
    return run_serve("llada-8b", "dllm-serve", "burst",
                     rps=4.0, n=6 if quick else 16, seed=0,
                     max_slots=6, size_by_profiler=False,
                     clock=clock, pipeline=pipeline)


def _step_us(r: dict) -> float:
    return 1e6 * r["wall_clock_s"] / max(r["iterations"], 1)


def run(quick: bool = True):
    sync = _serve(False, quick)
    pipe = _serve(True, quick)
    out = [
        ("engine/sync/step_time", _step_us(sync),
         f"{sync['iterations']}iters"),
        ("engine/pipelined/step_time", _step_us(pipe),
         f"{pipe['iterations']}iters"),
        ("engine/pipelined/overlap_frac", 0.0,
         f"{pipe['overlap_frac']:.4f}"),
        ("engine/pipelined/dispatched_ahead", 0.0,
         f"{pipe['dispatched_ahead']}/{pipe['iterations']}"),
        ("engine/wall_vs_modeled_tok_s", 0.0,
         f"{pipe['wall_tok_s']:.1f}wall/{pipe['throughput_tok_s']:.1f}mod"),
        ("engine/bit_identity", 0.0,
         "ok" if sync["committed_tokens"] == pipe["committed_tokens"]
         else "VIOLATED"),
    ]
    return out


def record(quick: bool = True) -> dict:
    sync = _serve(False, quick)
    pipe = _serve(True, quick)
    keys = ("rps", "n", "iterations", "committed_tokens",
            "throughput_tok_s", "wall_tok_s", "wall_clock_s",
            "host_plan_s", "host_fill_s", "sync_wait_s",
            "overlapped_host_s", "overlap_frac", "dispatched_ahead",
            "compiles_post_warmup", "max_slots")
    return {
        "sync": {k: sync[k] for k in keys},
        "pipelined": {k: pipe[k] for k in keys},
        # the loop restructure's two contracts, recorded so a regression
        # can't slip into the committed artifact unnoticed: dispatch-ahead
        # actually overlapped host work, and it changed zero tokens.
        "overlap_gain": pipe["overlap_frac"] - sync["overlap_frac"],
        "bit_identical": sync["committed_tokens"] == pipe["committed_tokens"]
        and sync["n_finished"] == pipe["n_finished"],
        "config": {"workload": "burst", "clock": "wall", "seed": 0,
                   "max_slots": 6},
    }
