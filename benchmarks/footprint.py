"""Paper Table 1: per-layer cache footprint per serving policy (analytic,
full LLaDA-8B geometry) + measured slot bytes from the engine pool, plus
the memory-footprint multipliers (docs/memory.md): shared-prefix dedup
and int8 slot storage converted into concurrent-slot capacity by
``plan_memory``. ``record(quick)`` commits the multiplier table as
``BENCH_footprint.json`` for diff_bench regression."""
import dataclasses

import numpy as np

from repro.configs import ARCHS, get_config, reduced
from repro.configs.base import ServeConfig
from repro.core.baselines import system_profiles
from repro.core.budgeting import kv_slot_bytes, plan_memory
from repro.data.workloads import make_trace, prefix_share_factor

HBM_GB = 48


def _capacity_plans():
    """plan_memory slot capacity at one HBM budget across the multiplier
    grid. The share factor is MEASURED from the shared-prefix trace (not
    assumed), so the recorded numbers move only if the workload or the
    planner move."""
    cfg = get_config("llada-8b")
    base = ServeConfig(max_seq_len=2048, max_slots=4096)
    share = prefix_share_factor(make_trace("shared-prefix", 64, rps=4.0,
                                           seed=0))
    variants = {
        "base": (base, 1.0),
        "int8": (dataclasses.replace(base, kv_quant="int8"), 1.0),
        "sharing": (dataclasses.replace(base, prefix_sharing=True), share),
        "sharing+int8": (dataclasses.replace(base, prefix_sharing=True,
                                             kv_quant="int8"), share),
    }
    plans = {name: plan_memory(cfg, serve, HBM_GB << 30, share_factor=sf)
             for name, (serve, sf) in variants.items()}
    return plans, share


def _measured_sharing():
    """Serve a lockstep shared-prefix burst through the refcounted pool:
    the physical peak must undercut the logical slot count, and the
    dedup/COW counters prove the ledger (not padding luck) did it."""
    from repro.core.engine import Engine
    rcfg = reduced(ARCHS["llada-8b"])
    serve = ServeConfig(max_num_batched_tokens=512, max_num_logits=64,
                        block_size=8, steps_per_block=8, max_seq_len=128,
                        max_slots=6, max_refresh_per_iter=2,
                        logit_mode="chunked", prefix_sharing=True)
    eng = Engine(rcfg, serve, seed=0)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, rcfg.vocab_size - 1, 24) for _ in range(3)]
    for i in range(6):
        eng.submit(prompts[i // 2], gen_len=16, arrival=0.0, rid=i)
    stats = eng.run()
    return dict(logical_slots=serve.max_slots,
                phys_slots_peak=stats.phys_slots_peak,
                shared_hits=stats.shared_hits,
                shared_cow_promotes=stats.shared_cow_promotes,
                committed_tokens=stats.committed_tokens)


def run(quick: bool = True):
    out = []
    cfg = get_config("llada-8b")
    base = ServeConfig(max_seq_len=2048)
    for name, serve in system_profiles(base).items():
        per_layer = kv_slot_bytes(cfg, serve) / cfg.n_layers
        out.append((f"footprint/{name}/per_layer", 0.0,
                    f"{per_layer/2**20:.1f}MiB(r={serve.retention_ratio})"))
    # measured: engine pool bytes for head vs dense retention
    from repro.core.engine import Engine
    rcfg = reduced(ARCHS["llada-8b"])
    for name, serve in [
        ("sparse_r0.5", dataclasses.replace(base, max_seq_len=128,
                                            retention_ratio=0.5,
                                            max_slots=4, block_size=8,
                                            steps_per_block=8)),
        ("dense_r1.0", dataclasses.replace(base, max_seq_len=128,
                                           retention_ratio=1.0, max_slots=4,
                                           block_size=8, steps_per_block=8,
                                           selection="none")),
    ]:
        eng = Engine(rcfg, serve, seed=0)
        eng.submit(np.arange(16, dtype=np.int32), gen_len=8)
        eng.run(max_iters=3)
        out.append((f"footprint/measured_pool/{name}", 0.0,
                    f"{eng.pool.nbytes()/2**20:.2f}MiB"))
    # memory-footprint multipliers: slot capacity at fixed HBM
    plans, share = _capacity_plans()
    for name, plan in plans.items():
        out.append((f"footprint/capacity/{name}", 0.0,
                    f"slots={plan.max_slots}(phys={plan.phys_slots},"
                    f"slot={plan.slot_bytes/2**20:.0f}MiB)"))
    out.append(("footprint/capacity/share_factor", 0.0, f"{share:.2f}x"))
    m = _measured_sharing()
    out.append(("footprint/measured_sharing", 0.0,
                f"phys_peak={m['phys_slots_peak']}/"
                f"{m['logical_slots']}logical"
                f"|hits={m['shared_hits']}|cow={m['shared_cow_promotes']}"))
    out.append(("footprint/claim", 0.0,
                "paper:ours=rL_contiguous_vs_L_for_dense_caches"))
    return out


def record(quick: bool = True) -> dict:
    """The committed-artifact view: the capacity-multiplier table plus the
    measured refcounted-pool run a regression harness should diff."""
    plans, share = _capacity_plans()
    return {
        "hbm_gb": HBM_GB,
        "share_factor": round(share, 4),
        "capacity": {name: {"max_slots": p.max_slots,
                            "phys_slots": p.phys_slots,
                            "slot_bytes": p.slot_bytes,
                            "kv_pool_bytes": p.kv_pool_bytes,
                            "kv_quant": p.kv_quant}
                     for name, p in plans.items()},
        "measured_sharing": _measured_sharing(),
        "config": {"arch": "llada-8b", "trace": "shared-prefix",
                   "trace_n": 64, "trace_rps": 4.0, "trace_seed": 0},
    }
