"""Paper Table 1: per-layer cache footprint per serving policy (analytic,
full LLaDA-8B geometry) + measured slot bytes from the engine pool."""
import dataclasses

import numpy as np

from repro.configs import ARCHS, get_config, reduced
from repro.configs.base import ServeConfig
from repro.core.baselines import system_profiles
from repro.core.budgeting import kv_slot_bytes


def run(quick: bool = True):
    out = []
    cfg = get_config("llada-8b")
    base = ServeConfig(max_seq_len=2048)
    for name, serve in system_profiles(base).items():
        per_layer = kv_slot_bytes(cfg, serve) / cfg.n_layers
        out.append((f"footprint/{name}/per_layer", 0.0,
                    f"{per_layer/2**20:.1f}MiB(r={serve.retention_ratio})"))
    # measured: engine pool bytes for head vs dense retention
    from repro.core.engine import Engine
    rcfg = reduced(ARCHS["llada-8b"])
    for name, serve in [
        ("sparse_r0.5", dataclasses.replace(base, max_seq_len=128,
                                            retention_ratio=0.5,
                                            max_slots=4, block_size=8,
                                            steps_per_block=8)),
        ("dense_r1.0", dataclasses.replace(base, max_seq_len=128,
                                           retention_ratio=1.0, max_slots=4,
                                           block_size=8, steps_per_block=8,
                                           selection="none")),
    ]:
        eng = Engine(rcfg, serve, seed=0)
        eng.submit(np.arange(16, dtype=np.int32), gen_len=8)
        eng.run(max_iters=3)
        out.append((f"footprint/measured_pool/{name}", 0.0,
                    f"{eng.pool.nbytes()/2**20:.2f}MiB"))
    out.append(("footprint/claim", 0.0,
                "paper:ours=rL_contiguous_vs_L_for_dense_caches"))
    return out
