"""Robustness benchmark: overload + chaos behaviour of the serving engine.

Two scenarios (docs/robustness.md):

* ``overload`` — a Burst trace at a saturating arrival rate through a
  bounded queue with deadlines and preempt-and-requeue enabled.  The row
  set reports the tail (p99), goodput next to raw throughput, the full
  outcome taxonomy (finished / shed / rejected / preempted), and the
  conservation law ``submitted == finished + shed + rejected`` — overload
  must degrade into structured outcomes, never an engine error.
* ``chaos`` — the same engine under a seeded ``FaultPlan`` (dispatch
  faults below the retry limit, alloc faults, mem-pressure slot steals,
  slow iterations).  Faults must be absorbed (retries, deferred
  admission) without breaking conservation.

``record(quick)`` returns the JSON dict committed as
``BENCH_robustness.json`` by ``benchmarks.run --record``.
"""
from repro.launch.serve import run_serve


def _overload(quick: bool = True) -> dict:
    # rps far beyond the admissible rate for 4 slots: the queue saturates
    # and the engine must shed.  size_by_profiler=False pins max_slots so
    # the recorded artifact is stable across profiler changes.
    return run_serve("llada-8b", "dllm-serve", "burst",
                     rps=8.0, n=16 if quick else 32, seed=0,
                     queue_cap=4, queue_policy="evict", deadline_slack=3.0,
                     preempt_starvation_s=0.5, max_slots=4,
                     size_by_profiler=False)


def _chaos(quick: bool = True) -> dict:
    return run_serve("llada-8b", "dllm-serve", "burst",
                     rps=2.0, n=8 if quick else 16, seed=0,
                     preempt_starvation_s=0.5, max_slots=4,
                     size_by_profiler=False, fault_seed=1)


def _conserved(r: dict) -> bool:
    return r["n_submitted"] == r["n_finished"] + r["n_shed"] + r["n_rejected"]


def run(quick: bool = True):
    out = []
    ov = _overload(quick)
    out.append(("robustness/overload/p99_latency_s", 0.0,
                f"{ov['p99_latency']:.3f}s"))
    out.append(("robustness/overload/goodput_tok_s", 0.0,
                f"{ov['goodput_tok_s']:.2f}good/"
                f"{ov['throughput_tok_s']:.2f}raw"))
    out.append(("robustness/overload/outcomes", 0.0,
                f"fin={ov['n_finished']}|shed={ov['n_shed']}"
                f"|rej={ov['n_rejected']}|preempt={ov['n_preemptions']}"))
    out.append(("robustness/overload/conservation", 0.0,
                "ok" if _conserved(ov) else "VIOLATED"))
    ch = _chaos(quick)
    out.append(("robustness/chaos/faults_absorbed", 0.0,
                f"retries={ch['dispatch_retries']}"
                f"|alloc_iters={ch['alloc_fault_iters']}"
                f"|recomputed={ch['recomputed_tokens']}"))
    out.append(("robustness/chaos/conservation", 0.0,
                "ok" if _conserved(ch) else "VIOLATED"))
    return out


def record(quick: bool = True) -> dict:
    """The committed-artifact view: scenario parameters + the stats a
    regression harness should diff."""
    ov, ch = _overload(quick), _chaos(quick)
    keys = ("rps", "n", "throughput_tok_s", "goodput_tok_s", "wall_time",
            "p50_latency", "p99_latency", "n_submitted", "n_finished",
            "n_shed", "n_rejected", "shed_deadline", "shed_queue",
            "rejected_oversized", "rejected_queue_full", "n_preemptions",
            "recomputed_tokens", "dispatch_retries", "alloc_fault_iters",
            "max_slots")
    return {
        "overload": {k: ov[k] for k in keys},
        "overload_conserved": _conserved(ov),
        "chaos": {k: ch[k] for k in keys},
        "chaos_conserved": _conserved(ch),
        "config": {"workload": "burst", "queue_cap": 4,
                   "queue_policy": "evict", "deadline_slack": 3.0,
                   "preempt_starvation_s": 0.5, "fault_seed_chaos": 1},
    }
