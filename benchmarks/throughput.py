"""Paper Fig.3 + Table 4: serving throughput per system/workload/arrival rate.

Reported: tok/s per cell, and dLLM-Serve's speedup over the best baseline
(the paper's headline: 1.61-1.81×)."""
from benchmarks._grid import SYSTEMS, WORKLOADS, best_baseline, grid, ours


def run(quick: bool = True):
    rows = grid(quick)
    out = []
    rps_points = sorted({r["rps"] for r in rows})
    for wl in WORKLOADS:
        for rps in rps_points:
            for s in SYSTEMS:
                r = [x for x in rows
                     if (x["workload"], x["system"], x["rps"]) == (wl, s, rps)][0]
                us_per_tok = 1e6 / max(r["throughput_tok_s"], 1e-9)
                out.append((f"throughput/{wl}/rps{rps}/{s}", us_per_tok,
                            f"{r['throughput_tok_s']:.2f}tok_s"))
        hi_rps = rps_points[-1]
        speedup = ours(rows, wl, hi_rps) / best_baseline(rows, wl, hi_rps)
        out.append((f"throughput/{wl}/speedup_vs_best_baseline", 0.0,
                    f"{speedup:.2f}x(paper:1.61-1.81x)"))
        # padded-vs-packed Refresh token accounting (§4.1 flattened engine):
        # dllm-serve runs the token-packed path, baselines pay the padded
        # [batch_bucket × max_seq_len] rectangle
        us = [r for r in rows
              if r["workload"] == wl and r["rps"] == hi_rps
              and r["system"] == "dllm-serve"][0]
        base = [r for r in rows
                if r["workload"] == wl and r["rps"] == hi_rps
                and r["system"] == "fast-dllm"][0]
        if "refresh_waste" in us:
            out.append((f"throughput/{wl}/refresh_exec_tokens_packed", 0.0,
                        f"{us['refresh_tokens_exec']}exec/"
                        f"{us['refresh_tokens_real']}real="
                        f"{us['refresh_waste']:.3f}x"))
            out.append((f"throughput/{wl}/refresh_exec_tokens_padded", 0.0,
                        f"{base['refresh_tokens_exec']}exec/"
                        f"{base['refresh_tokens_real']}real="
                        f"{base['refresh_waste']:.3f}x"))
    return out
