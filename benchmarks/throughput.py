"""Paper Fig.3 + Table 4: serving throughput per system/workload/arrival rate.

Reported: tok/s per cell, dLLM-Serve's speedup over the best baseline (the
paper's headline: 1.61-1.81×), per-arch packed-vs-padded waste rows —
one family per execution path (attention stream, segment-reset SSD scan,
hybrid, frontend-prefix segments) so a packing regression in any path shows
up as a per-arch waste ratio, not just in the llada-only grid — and mesh
rows (1×1 vs 1×2 host-device subprocess runs: per-device exec tokens +
modeled throughput, tracking the sharded-serving trajectory).

Flags and the row schema are documented in ``docs/benchmarks.md``."""
from benchmarks._grid import SYSTEMS, WORKLOADS, best_baseline, grid, ours
from repro.launch.serve import run_serve

# one arch per packed execution path: dense attention, SSM scan, hybrid,
# vlm (frontend-prefix), audio (frontend-prefix)
WASTE_ARCHS = ("llada-8b", "mamba2-130m", "zamba2-7b",
               "internvl2-76b", "musicgen-medium")


def per_arch_waste(quick: bool = True):
    """``throughput/arch_waste/<arch>/<stage>`` rows: packed (dllm-serve)
    vs padded (fast-dllm) exec/real token ratios per stage, per arch, on
    the same burst trace. The packed engine must never waste more than the
    padded baseline on any stage for any family."""
    archs = WASTE_ARCHS[:2] + WASTE_ARCHS[3:4] if quick else WASTE_ARCHS
    out = []
    skipped = [a for a in WASTE_ARCHS if a not in archs]
    if skipped:
        # no silent coverage caps: quick mode drops the hybrid/audio archs,
        # and the output must say so (--full runs all of WASTE_ARCHS)
        out.append(("throughput/arch_waste/skipped_in_quick_mode", 0.0,
                    "+".join(skipped)))
    for arch in archs:
        res = {}
        for sys_name in ("dllm-serve", "fast-dllm"):
            res[sys_name] = run_serve(
                arch, sys_name, "burst", 2.0, 8, max_seq_len=192,
                block_size=8, steps_per_block=8, max_slots=8,
                max_num_batched_tokens=768, max_num_logits=96,
                length_scale=0.12)
        pk, pd = res["dllm-serve"], res["fast-dllm"]
        for stage in ("refresh", "reuse", "logit"):
            out.append((
                f"throughput/arch_waste/{arch}/{stage}", 0.0,
                f"packed={pk[f'{stage}_waste']:.3f}x"
                f"(exec{pk[f'{stage}_tokens_exec']}/"
                f"real{pk[f'{stage}_tokens_real']})"
                f"|padded={pd[f'{stage}_waste']:.3f}x"))
        out.append((f"throughput/arch_waste/{arch}/padded_refresh_calls",
                    0.0, f"packed_path={pk['padded_refresh_calls']}"))
    return out


_MESH_SERVE_CACHE = {}
MESH_RPS = 256.0


def _mesh_serve(mesh: str, n: int, kernels: bool) -> dict:
    """One serve subprocess on a CPU host-device mesh (memoized: ``run`` and
    ``record`` share the same measurements within one harness process).

    ``kernels=True`` forces the Pallas hot paths (``--kernels``: shard_mapped
    flash varlen attention + fused vocab-sharded argmax); ``kernels=False``
    pins the jnp per-shard fallback (chunked logits, masked-stream
    attention). A mesh that silently collapses to fewer devices than
    requested — or a kernels run where the engine fell back — raises."""
    key = (mesh, n, kernels)
    if key in _MESH_SERVE_CACHE:
        return _MESH_SERVE_CACHE[key]
    import json
    import os
    import subprocess
    import sys
    import tempfile
    # pin the CPU platform: --xla_force_host_platform_device_count is a
    # no-op on a GPU/TPU backend (the mesh would fail to build); append to
    # any pre-existing XLA_FLAGS rather than clobbering them
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=2").strip()
    env.pop("REPRO_MESH", None)      # --mesh below is authoritative
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        path = f.name
    try:
        # all-at-once burst (rps >> the _grid sweep's rps≈6 wall): an
        # arrival-dominated trace would show no modeled-clock separation
        # between mesh sizes, and staggered arrivals de-synchronize the
        # per-iteration Refresh sets into single-segment dispatches — where
        # the tile-skipping kernel and the jnp [T, T] rectangle coincide.
        # Simultaneous arrivals keep requests in refresh lockstep, so fused
        # dispatches carry multiple segments and the kernels' Σ Sᵢ² vs
        # (Σ Sᵢ)² modeled-cost gap is actually exercised.
        cmd = [sys.executable, "-m", "repro.launch.serve",
               "--arch", "llada-8b", "--system", "dllm-serve",
               "--workload", "burst", "--rps", str(MESH_RPS), "--n", str(n),
               "--mesh", mesh, "--out", path]
        if kernels:
            cmd.append("--kernels")
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           timeout=900)
        if r.returncode != 0:
            raise RuntimeError(
                f"mesh={mesh} kernels={kernels} serve failed: "
                f"{r.stderr[-1000:]}")
        with open(path) as f:
            res = json.load(f)
    finally:
        os.unlink(path)
    want = 1
    for d in mesh.split(","):
        want *= int(d)
    if res["mesh_devices"] != want:
        raise RuntimeError(
            f"mesh {mesh} collapsed to {res['mesh_devices']} device(s)")
    if res["kernels_active"] != kernels:
        raise RuntimeError(
            f"mesh {mesh}: kernels_active={res['kernels_active']} but "
            f"kernels={kernels} was requested — silent fallback")
    _MESH_SERVE_CACHE[key] = res
    return res


def mesh_rows(quick: bool = True):
    """``throughput/mesh/<shape>/...`` rows: the same burst trace served on
    a 1×1 vs 1×2 host-device mesh (CPU subprocesses under
    ``--xla_force_host_platform_device_count=2``), reporting per-device exec
    tokens, profiler-sized slots, p99 latency, and modeled throughput — the
    sharded-serving perf trajectory. The mesh signal shows up three ways:
    per-device exec tokens halve (TP splits the work), the per-device memory
    plan buys ~2× slots (capacity coupling), and latency/throughput improve
    once the trace pressures the 1-device slot count. Each mesh shape is
    served twice — jnp per-shard fallback vs the shard_mapped Pallas hot
    paths (``kernels_modeled_tok_s``) — so the kernels-×-TP win is a tracked
    row, not prose."""
    n = 12 if quick else 24          # > the 1-device slot plan: slot-bound
    out = []
    for mesh in ("1,1", "1,2"):
        tag = mesh.replace(",", "x")
        res = _mesh_serve(mesh, n, kernels=False)
        us_per_tok = 1e6 / max(res["throughput_tok_s"], 1e-9)
        out.append((f"throughput/mesh/{tag}/modeled_tok_s", us_per_tok,
                    f"{res['throughput_tok_s']:.2f}tok_s"
                    f"|devices={res['mesh_devices']}"
                    f"|slots={res['max_slots']}"
                    f"|p99={res['p99_latency']:.3f}s"))
        for stage in ("refresh", "reuse", "logit"):
            out.append((
                f"throughput/mesh/{tag}/{stage}_exec_tokens_per_device", 0.0,
                f"{res[f'{stage}_tokens_exec_per_device']:.0f}"
                f"(total{res[f'{stage}_tokens_exec']})"))
        kres = _mesh_serve(mesh, n, kernels=True)
        kus = 1e6 / max(kres["throughput_tok_s"], 1e-9)
        speed = kres["throughput_tok_s"] / max(res["throughput_tok_s"], 1e-9)
        out.append((f"throughput/mesh/{tag}/kernels_modeled_tok_s", kus,
                    f"{kres['throughput_tok_s']:.2f}tok_s"
                    f"|vs_jnp={speed:.2f}x"
                    f"|kernels_active={kres['kernels_active']}"))
    return out


def record(quick: bool = True) -> dict:
    """``BENCH_throughput.json`` snapshot: the mesh × kernels grid — the
    committed perf-trajectory artifact for the throughput area. Each mesh
    shape carries the jnp per-shard fallback and the shard_mapped Pallas
    run; ``kernels_speedup`` is the headline kernels-×-TP ratio."""
    n = 12 if quick else 24
    snap = {"schema": "throughput/mesh-kernels/v1", "workload": "burst",
            "rps": MESH_RPS, "n_requests": n, "arch": "llada-8b",
            "system": "dllm-serve", "rows": {}}
    for mesh in ("1,1", "1,2"):
        tag = mesh.replace(",", "x")
        jnp_res = _mesh_serve(mesh, n, kernels=False)
        k_res = _mesh_serve(mesh, n, kernels=True)
        snap["rows"][tag] = {
            "devices": jnp_res["mesh_devices"],
            "slots": jnp_res["max_slots"],
            "jnp_modeled_tok_s": round(jnp_res["throughput_tok_s"], 3),
            "kernels_modeled_tok_s": round(k_res["throughput_tok_s"], 3),
            "kernels_active": k_res["kernels_active"],
            "kernels_speedup": round(
                k_res["throughput_tok_s"]
                / max(jnp_res["throughput_tok_s"], 1e-9), 3),
            "jnp_p99_latency_s": round(jnp_res["p99_latency"], 4),
            "kernels_p99_latency_s": round(k_res["p99_latency"], 4),
            "refresh_exec_tokens_per_device": round(
                jnp_res["refresh_tokens_exec_per_device"], 1),
        }
    return snap


def run(quick: bool = True):
    rows = grid(quick)
    out = []
    rps_points = sorted({r["rps"] for r in rows})
    for wl in WORKLOADS:
        for rps in rps_points:
            for s in SYSTEMS:
                r = [x for x in rows
                     if (x["workload"], x["system"], x["rps"]) == (wl, s, rps)][0]
                us_per_tok = 1e6 / max(r["throughput_tok_s"], 1e-9)
                # outcome/goodput keys via .get(): a serve_grid.json cached
                # before the robustness layer lacks them — raw tok/s rows
                # must keep printing (delete the cache to refresh)
                good = r.get("goodput_tok_s")
                detail = f"{r['throughput_tok_s']:.2f}tok_s"
                if good is not None:
                    detail += (f"|good={good:.2f}"
                               f"|fin={r.get('n_finished', '?')}"
                               f"|shed={r.get('n_shed', '?')}"
                               f"|rej={r.get('n_rejected', '?')}")
                out.append((f"throughput/{wl}/rps{rps}/{s}", us_per_tok,
                            detail))
        hi_rps = rps_points[-1]
        speedup = ours(rows, wl, hi_rps) / best_baseline(rows, wl, hi_rps)
        out.append((f"throughput/{wl}/speedup_vs_best_baseline", 0.0,
                    f"{speedup:.2f}x(paper:1.61-1.81x)"))
        # padded-vs-packed Refresh token accounting (§4.1 flattened engine):
        # dllm-serve runs the token-packed path, baselines pay the padded
        # [batch_bucket × max_seq_len] rectangle
        us = [r for r in rows
              if r["workload"] == wl and r["rps"] == hi_rps
              and r["system"] == "dllm-serve"][0]
        base = [r for r in rows
                if r["workload"] == wl and r["rps"] == hi_rps
                and r["system"] == "fast-dllm"][0]
        if "refresh_waste" in us:
            out.append((f"throughput/{wl}/refresh_exec_tokens_packed", 0.0,
                        f"{us['refresh_tokens_exec']}exec/"
                        f"{us['refresh_tokens_real']}real="
                        f"{us['refresh_waste']:.3f}x"))
            out.append((f"throughput/{wl}/refresh_exec_tokens_padded", 0.0,
                        f"{base['refresh_tokens_exec']}exec/"
                        f"{base['refresh_tokens_real']}real="
                        f"{base['refresh_waste']:.3f}x"))
    out.extend(per_arch_waste(quick))
    out.extend(mesh_rows(quick))
    return out
