"""Whole-iteration token-packed vs padded execution (§4.1 flattened engine).

Runs the SAME ragged workload through both real execution paths and reports
per-stage token accounting — executed vs true tokens for Refresh, Reuse, and
the logit stage. The packed pipeline must stay within one ``token_bucket``
of the true token count per dispatch on every stage; the padded oracle pays
pow2 rectangles (``batch_bucket × max_seq_len`` for Refresh, pow2 request
batches for Reuse, pow2 row buckets for logits). Measured wall time per
Refresh step is reported for this host (CPU: directionally useful only; the
token ratios are the device-independent signal).

``python -m benchmarks.packing --smoke --out packing_smoke.json`` runs the
CI gate: asserts ``refresh_waste``/``reuse_waste``/``logit_waste`` of the
packed engine are each ≤ the padded baseline — for an attention config, an
SSM config (the segment-reset varlen scan path), AND a modality-frontend
config (the frontend-prefix segment path), so every family's packing is
enforced — writes the per-arch JSON rows, and exits non-zero if any
``SMOKE_ARCHS`` row is missing from the artifact.

Entry points, flags, and the JSON row schema are documented in
``docs/benchmarks.md``.
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np


def _serve(varlen: bool):
    from repro.configs.base import ServeConfig
    return ServeConfig(
        max_num_batched_tokens=1024, max_num_logits=128, block_size=8,
        steps_per_block=8, max_seq_len=192, max_slots=8,
        max_refresh_per_iter=4, selection="head", scheduler="phase",
        logit_mode="chunked", varlen_pack=varlen, token_bucket=32)


# the smoke gate covers one attention family, one scan family (the
# segment-reset SSD scan path), and one modality-frontend family (the
# frontend-prefix segment path): packed refresh/reuse/logit waste must beat
# the padded oracle for ALL of them
SMOKE_ARCHS = ("llada-8b", "mamba2-130m", "internvl2-76b")


def _run_one(varlen: bool, n: int, seed: int = 0,
             arch: str = "llada-8b") -> dict:
    from repro.configs import ARCHS, reduced
    from repro.core.engine import Engine

    cfg = reduced(ARCHS[arch])
    eng = Engine(cfg, _serve(varlen), seed=seed)
    eng.warmup()
    rng = np.random.default_rng(seed)
    plens = [int(rng.integers(48, 160)) for _ in range(n)]

    def wave(rid0):
        r2 = np.random.default_rng(seed)
        for i, plen in enumerate(plens):
            eng.submit(r2.integers(0, cfg.vocab_size - 1, plen),
                       gen_len=16, arrival=0.0, rid=rid0 + i)
        t0 = time.perf_counter()
        stats = eng.run()
        return time.perf_counter() - t0, stats

    def snap(s):
        return dict(
            calls=s.packed_refresh_calls + s.padded_refresh_calls,
            refresh_real=s.refresh_tokens_real,
            refresh_exec=s.refresh_tokens_exec,
            reuse_real=s.reuse_tokens_real, reuse_exec=s.reuse_tokens_exec,
            logit_real=s.logit_tokens_real, logit_exec=s.logit_tokens_exec,
            committed=s.committed_tokens)

    # wave 1 triggers the lazy per-bucket compiles; wave 2 replays the same
    # length distribution and is the measured steady state (EngineStats is
    # engine-lifetime, so every reported number is a wave-2 delta)
    _, s1 = wave(0)
    w1 = snap(s1)
    wall, s2 = wave(n)
    w2 = snap(s2)
    d = {k: w2[k] - w1[k] for k in w1}
    out = dict(
        real=d["refresh_real"],
        exec=d["refresh_exec"],
        refresh_waste=d["refresh_exec"] / max(d["refresh_real"], 1),
        reuse_waste=d["reuse_exec"] / max(d["reuse_real"], 1),
        logit_waste=d["logit_exec"] / max(d["logit_real"], 1),
        calls=d["calls"],
        us_per_refresh=1e6 * wall / max(d["calls"], 1),
        committed=d["committed"],
        wall=wall,
    )
    for k in ("reuse_real", "reuse_exec", "logit_real", "logit_exec"):
        out[k] = d[k]
    return out


def run(quick: bool = True):
    n = 8 if quick else 24
    packed = _run_one(True, n)
    padded = _run_one(False, n)
    out = [
        ("packing/packed/refresh_tokens_exec", packed["us_per_refresh"],
         f"{packed['exec']}exec/{packed['real']}real="
         f"{packed['refresh_waste']:.3f}x"),
        ("packing/padded/refresh_tokens_exec", padded["us_per_refresh"],
         f"{padded['exec']}exec/{padded['real']}real="
         f"{padded['refresh_waste']:.3f}x"),
        ("packing/packed/reuse_waste", 0.0,
         f"{packed['reuse_exec']}exec/{packed['reuse_real']}real="
         f"{packed['reuse_waste']:.3f}x"),
        ("packing/padded/reuse_waste", 0.0,
         f"{padded['reuse_exec']}exec/{padded['reuse_real']}real="
         f"{padded['reuse_waste']:.3f}x"),
        ("packing/packed/logit_waste", 0.0,
         f"{packed['logit_exec']}exec/{packed['logit_real']}real="
         f"{packed['logit_waste']:.3f}x"),
        ("packing/padded/logit_waste", 0.0,
         f"{padded['logit_exec']}exec/{padded['logit_real']}real="
         f"{padded['logit_waste']:.3f}x"),
        ("packing/exec_token_ratio_padded_over_packed", 0.0,
         f"{padded['exec'] / max(packed['exec'], 1):.2f}x"),
        ("packing/step_time_ratio_padded_over_packed", 0.0,
         f"{padded['us_per_refresh'] / max(packed['us_per_refresh'], 1e-9):.2f}x"),
        ("packing/packed_flops_within_10pct_of_true", 0.0,
         str(packed["refresh_waste"] <= 1.10)),
    ]
    assert packed["committed"] == padded["committed"], \
        (packed["committed"], padded["committed"])
    return out


def check_rows(rows: dict) -> None:
    """Fail LOUDLY (non-zero exit) if any ``SMOKE_ARCHS`` row is missing or
    unverified — a silently absent arch row would let the CI artifact claim
    coverage the gate never ran."""
    missing = [a for a in SMOKE_ARCHS
               if a not in rows or not rows[a].get("ok")]
    if missing:
        raise SystemExit(
            f"packing smoke artifact is missing verified rows for "
            f"{missing} (have: {sorted(k for k in rows if k != 'ok')})")


def smoke(out_path: str | None = None) -> dict:
    """CI gate: the packed engine's per-stage waste must never exceed the
    padded baseline on the same ragged workload, for every ``SMOKE_ARCHS``
    family (attention, SSM, and modality-frontend). Returns (and optionally
    writes) the per-arch comparison rows; a missing arch row exits
    non-zero."""
    rows: dict = {}
    for arch in SMOKE_ARCHS:
        packed = _run_one(True, 8, arch=arch)
        padded = _run_one(False, 8, arch=arch)
        row = dict(packed=packed, padded=padded)
        assert packed["committed"] == padded["committed"], (arch, row)
        for stage in ("refresh_waste", "reuse_waste", "logit_waste"):
            assert packed[stage] <= padded[stage] + 1e-9, (arch, stage, row)
        row["ok"] = True
        rows[arch] = row
    rows["ok"] = True
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=1)
        # re-read the artifact and verify every arch row landed in it — the
        # gate must fail even if the miss is in serialization, not the runs
        with open(out_path) as f:
            check_rows(json.load(f))
    else:
        check_rows(rows)
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="assert packed waste ≤ padded per stage, emit JSON")
    ap.add_argument("--out", default=None)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        rows = smoke(args.out)
        for arch in SMOKE_ARCHS:
            p, d = rows[arch]["packed"], rows[arch]["padded"]
            for stage in ("refresh_waste", "reuse_waste", "logit_waste"):
                print(f"{arch}/{stage}: packed={p[stage]:.3f}x "
                      f"padded={d[stage]:.3f}x")
        print("smoke ok")
        return
    for name, us, derived in run(quick=not args.full):
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
