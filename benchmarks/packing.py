"""Token-packed vs padded Refresh execution (§4.1 flattened engine).

Runs the SAME ragged workload through both real execution paths and reports:

  * token accounting — executed vs true Refresh tokens per path. The packed
    path must stay within one ``token_bucket`` of ``Σ total_len`` per
    dispatch (FLOPs within ~10% of the true-token sum for realistic chunk
    sizes); the padded oracle pays ``batch_bucket × max_seq_len``.
  * measured wall time per Refresh step on this host (CPU: directionally
    useful only; the token ratio is the device-independent signal).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


def _serve(varlen: bool):
    from repro.configs.base import ServeConfig
    return ServeConfig(
        max_num_batched_tokens=1024, max_num_logits=128, block_size=8,
        steps_per_block=8, max_seq_len=192, max_slots=8,
        max_refresh_per_iter=4, selection="head", scheduler="phase",
        logit_mode="chunked", varlen_pack=varlen, token_bucket=32)


def _run_one(varlen: bool, n: int, seed: int = 0) -> dict:
    from repro.configs import ARCHS, reduced
    from repro.core.engine import Engine

    cfg = reduced(ARCHS["llada-8b"])
    eng = Engine(cfg, _serve(varlen), seed=seed)
    eng.warmup()
    rng = np.random.default_rng(seed)
    plens = [int(rng.integers(48, 160)) for _ in range(n)]

    def wave(rid0):
        r2 = np.random.default_rng(seed)
        for i, plen in enumerate(plens):
            eng.submit(r2.integers(0, cfg.vocab_size - 1, plen),
                       gen_len=16, arrival=0.0, rid=rid0 + i)
        t0 = time.perf_counter()
        stats = eng.run()
        return time.perf_counter() - t0, stats

    # wave 1 triggers the lazy per-bucket compiles; wave 2 replays the same
    # length distribution and is the measured steady state (EngineStats is
    # engine-lifetime, so every reported number is a wave-2 delta)
    _, s1 = wave(0)
    calls1 = s1.packed_refresh_calls + s1.padded_refresh_calls
    real1, exec1 = s1.refresh_tokens_real, s1.refresh_tokens_exec
    committed1 = s1.committed_tokens
    wall, s2 = wave(n)
    calls = (s2.packed_refresh_calls + s2.padded_refresh_calls) - calls1
    real = s2.refresh_tokens_real - real1
    exc = s2.refresh_tokens_exec - exec1
    return dict(
        real=real,
        exec=exc,
        waste=exc / max(real, 1),
        calls=calls,
        us_per_refresh=1e6 * wall / max(calls, 1),
        committed=s2.committed_tokens - committed1,
        wall=wall,
    )


def run(quick: bool = True):
    n = 8 if quick else 24
    packed = _run_one(True, n)
    padded = _run_one(False, n)
    out = [
        ("packing/packed/refresh_tokens_exec", packed["us_per_refresh"],
         f"{packed['exec']}exec/{packed['real']}real={packed['waste']:.3f}x"),
        ("packing/padded/refresh_tokens_exec", padded["us_per_refresh"],
         f"{padded['exec']}exec/{padded['real']}real={padded['waste']:.3f}x"),
        ("packing/exec_token_ratio_padded_over_packed", 0.0,
         f"{padded['exec'] / max(packed['exec'], 1):.2f}x"),
        ("packing/step_time_ratio_padded_over_packed", 0.0,
         f"{padded['us_per_refresh'] / max(packed['us_per_refresh'], 1e-9):.2f}x"),
        ("packing/packed_flops_within_10pct_of_true", 0.0,
         str(packed["waste"] <= 1.10)),
    ]
    assert packed["committed"] == padded["committed"], \
        (packed["committed"], padded["committed"])
    return out
