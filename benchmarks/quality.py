"""Paper Fig.6: head-centric vs uniform selection quality across retention.

No model weights/datasets ship offline (HumanEval/GSM8K impossible), so we
use two proxies that isolate exactly what Fig.6 measures — whether per-head
selection preserves information that head-aggregated selection destroys:

  1. **Attention fidelity**: mean |reuse_hidden(sparse) − reuse_hidden(dense)|
     on a reduced model, across r ∈ {0.1..0.5}. Lower = better.
  2. **Head-disjoint retrieval**: synthetic K/V where each head's critical
     token is salient only to that head. Recovery rate of critical tokens
     under each policy (accuracy-like, higher = better; uniform provably
     drops minority-head tokens at low r).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import backbone as BB
from repro.models import transformer as T
from repro.models.sparse_select import select_indices

RETENTIONS = (0.1, 0.2, 0.3, 0.4, 0.5)


def attention_fidelity(selection: str, r: float, seed: int = 0):
    cfg = reduced(ARCHS["llada-8b"], n_layers=3, d_model=96, n_heads=6,
                  n_kv_heads=6, head_dim=16)
    key = jax.random.PRNGKey(seed)
    params = BB.init_params(cfg, key)
    B, S, Sb = 4, 128, 8
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    bs = jnp.array([32, 48, 64, 96], dtype=jnp.int32)
    btoks = jax.vmap(lambda t, s: jax.lax.dynamic_slice_in_dim(t, s, Sb))(
        tokens, bs)
    bpos = bs[:, None] + jnp.arange(Sb)[None]

    def reuse_h(sel, retain):
        ctx = T.ServeContext(block_size=Sb, retain=retain, selection=sel,
                             q_chunk=S)
        out = BB.serve_refresh(params, cfg, tokens, bs, ctx)
        return BB.serve_reuse(params, cfg, btoks, bpos, out.cache, ctx)

    dense = reuse_h("none", S - Sb)
    sparse = reuse_h(selection, max(8, int(S * r)))
    scale = float(jnp.mean(jnp.abs(dense))) + 1e-9
    return float(jnp.mean(jnp.abs(sparse - dense))) / scale


def head_disjoint_recovery(mode: str, r: float, seed: int = 0,
                           K: int = 8, S: int = 128) -> float:
    """Each KV head h has `per_head` critical positions whose keys align
    with that head's query only. Fraction of critical positions retained."""
    rng = jax.random.PRNGKey(seed)
    dh, Sb = 16, 4
    q = jax.random.normal(rng, (1, Sb, K, dh)) * 0.01
    k = jax.random.normal(jax.random.fold_in(rng, 1), (1, S, K, dh)) * 0.01
    per_head = 4
    crit = {}
    for h in range(K):
        pos = 8 + h * per_head + np.arange(per_head)
        crit[h] = pos
        # make these keys salient to head h only
        k = k.at[0, pos, h].set(np.asarray(
            jax.random.normal(jax.random.fold_in(rng, 100 + h), (per_head, dh))) * 3.0)
        q = q.at[0, :, h].set(np.asarray(k[0, pos[0], h]))
    from repro.models.sparse_select import head_scores
    scores = head_scores(q, k, kernel_size=1)
    retain = max(per_head, int(S * r))
    idx = select_indices(scores, retain, mode=mode,
                         exclude=jnp.zeros((1, S), bool))
    idx = np.asarray(idx)[0]
    hits = sum(np.isin(crit[h], idx[h]).sum() for h in range(K))
    return hits / (K * per_head)


def run(quick: bool = True):
    out = []
    rets = RETENTIONS if not quick else (0.1, 0.3, 0.5)
    for r in rets:
        eh = attention_fidelity("head", r)
        eu = attention_fidelity("uniform", r)
        out.append((f"quality/fidelity_err/r{r}/head", 0.0, f"{eh:.4f}"))
        out.append((f"quality/fidelity_err/r{r}/uniform", 0.0, f"{eu:.4f}"))
        rh = np.mean([head_disjoint_recovery("head", r, s) for s in range(3)])
        ru = np.mean([head_disjoint_recovery("uniform", r, s) for s in range(3)])
        out.append((f"quality/recovery/r{r}/head", 0.0, f"{rh*100:.1f}%"))
        out.append((f"quality/recovery/r{r}/uniform", 0.0, f"{ru*100:.1f}%"))
    out.append(("quality/claim", 0.0,
                "paper:+87.7%_rel_GSM8K@r=0.1_head_vs_uniform"))
    return out
