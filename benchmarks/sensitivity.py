"""Paper Fig.7: relative throughput (dLLM-Serve / Sparse-dLLM) vs input and
output length. The paper observes speedups decaying from ~3.1x to ~2.5x as
lengths grow (longer atomic Refresh phases are harder to interleave)."""
from repro.launch.serve import run_serve


def _pair(workload, in_len, out_len, seed=0):
    kw = dict(max_seq_len=256, block_size=8, steps_per_block=8, max_slots=10,
              max_num_batched_tokens=1024, max_num_logits=128,
              length_scale=1.0, time_scale=0.02)
    import repro.data.workloads as W
    orig = W.make_trace

    def fixed_trace(name, n, rps, seed=0, scale=1.0):
        tr = orig(name, n, rps, seed, scale)
        return [W.TraceRequest(t.arrival, in_len, out_len) for t in tr]

    W.make_trace = fixed_trace
    try:
        ours = run_serve("llada-8b", "dllm-serve", workload, 2.0, 8,
                         seed=seed, **kw)
        base = run_serve("llada-8b", "sparse-dllm", workload, 2.0, 8,
                         seed=seed, **kw)
    finally:
        W.make_trace = orig
    return ours["throughput_tok_s"] / max(base["throughput_tok_s"], 1e-9)


def run(quick: bool = True):
    out = []
    in_lens = (16, 64, 128) if quick else (16, 32, 64, 96, 128)
    for il in in_lens:
        sp = _pair("livebench", il, 32)
        out.append((f"sensitivity/input_len{il}", 0.0,
                    f"{sp:.2f}x_vs_sparse-dllm"))
    out_lens = (16, 64) if quick else (16, 32, 64, 96)
    for ol in out_lens:
        sp = _pair("livebench", 48, ol)
        out.append((f"sensitivity/output_len{ol}", 0.0,
                    f"{sp:.2f}x_vs_sparse-dllm"))
    out.append(("sensitivity/claim", 0.0,
                "paper:3.1x->2.45x_decaying_with_input_len"))
    return out
