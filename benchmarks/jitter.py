"""Paper Fig.5: jitter & predictability under high load — latency std and
tail span (max-min), normalized to the best baseline (higher=better)."""
from benchmarks._grid import WORKLOADS, best_baseline, grid, ours


def run(quick: bool = True):
    rows = grid(quick)
    out = []
    hi = sorted({r["rps"] for r in rows})[-1]
    for wl in WORKLOADS:
        std_gain = best_baseline(rows, wl, hi, "latency_std", hi=False) / \
            max(ours(rows, wl, hi, "latency_std"), 1e-9)
        span_gain = best_baseline(rows, wl, hi, "tail_span", hi=False) / \
            max(ours(rows, wl, hi, "tail_span"), 1e-9)
        out.append((f"jitter/{wl}/std_gain", 0.0,
                    f"{std_gain:.2f}x(paper:~2.3x_livebench)"))
        out.append((f"jitter/{wl}/tail_span_gain", 0.0,
                    f"{span_gain:.2f}x(paper:~2.1x_livebench)"))
    return out
