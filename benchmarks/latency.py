"""Paper Fig.4: average end-to-end latency vs arrival rate."""
from benchmarks._grid import SYSTEMS, WORKLOADS, best_baseline, grid, ours


def run(quick: bool = True):
    rows = grid(quick)
    out = []
    rps_points = sorted({r["rps"] for r in rows})
    for wl in WORKLOADS:
        for rps in rps_points:
            for s in SYSTEMS:
                r = [x for x in rows
                     if (x["workload"], x["system"], x["rps"]) == (wl, s, rps)][0]
                out.append((f"latency/{wl}/rps{rps}/{s}",
                            r["avg_latency"] * 1e6,
                            f"avg={r['avg_latency']:.2f}s p99={r['p99_latency']:.2f}s"))
        hi = rps_points[-1]
        red = best_baseline(rows, wl, hi, "avg_latency", hi=False) / \
            max(ours(rows, wl, hi, "avg_latency"), 1e-9)
        out.append((f"latency/{wl}/reduction_vs_best_baseline", 0.0,
                    f"{red:.2f}x(paper:~3-4x_under_contention)"))
    return out
