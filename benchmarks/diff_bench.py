"""Benchmark-regression gate: diff committed BENCH_*.json against git.

Every benchmark module records its results into a committed
``BENCH_<area>.json`` (see ``benchmarks/run.py --record``). This tool
compares the work-tree snapshots against the same file at a previous git
revision (default ``HEAD~1``, i.e. "what this PR changes") and fails on any
tracked metric regressing beyond the threshold:

* keys containing ``tok_s`` / ``goodput`` / ``speedup``: higher is better —
  regression = new < old × (1 − threshold);
* keys containing ``p99`` / ``p50`` / ``latency`` / ``wall_time``: lower is
  better — regression = new > old × (1 + threshold).

Keys are matched recursively by dotted path; metrics present on only one
side are reported but never fail (a new benchmark is not a regression).
Baselines of zero are skipped (no meaningful ratio). Exit 0 = no regression
(including "no previous revision has this file" on a fresh history).

CI wiring (``.github/workflows/ci.yml`` Analysis gate)::

    python benchmarks/diff_bench.py --base origin/main --threshold 0.10
"""
from __future__ import annotations

import argparse
import glob
import json
import subprocess
import sys
from typing import Dict, Iterator, Tuple

HIGHER = ("tok_s", "goodput", "speedup")
LOWER = ("p99", "p50", "latency", "wall_time")


def _flatten(d: dict, prefix: str = "") -> Iterator[Tuple[str, float]]:
    for k, v in sorted(d.items()):
        path = f"{prefix}.{k}" if prefix else str(k)
        if isinstance(v, dict):
            yield from _flatten(v, path)
        elif isinstance(v, (int, float)) and not isinstance(v, bool):
            yield path, float(v)


def _tracked(path: str) -> str:
    """'higher' | 'lower' | '' for untracked."""
    leaf = path.rsplit(".", 1)[-1]
    if any(t in leaf for t in HIGHER):
        return "higher"
    if any(t in leaf for t in LOWER):
        return "lower"
    return ""


def _git_show(rev: str, path: str) -> dict | None:
    try:
        blob = subprocess.run(
            ["git", "show", f"{rev}:{path}"], capture_output=True,
            text=True, check=True).stdout
    except subprocess.CalledProcessError:
        return None        # file didn't exist at that revision
    try:
        return json.loads(blob)
    except json.JSONDecodeError:
        return None


def diff_file(path: str, base: str, threshold: float) -> list:
    """Returns a list of regression dicts for one BENCH file."""
    old = _git_show(base, path)
    if old is None:
        print(f"{path}: no baseline at {base} (new file) — skipped")
        return []
    with open(path) as fh:
        new = json.load(fh)
    old_m = dict(_flatten(old))
    new_m = dict(_flatten(new))
    regressions = []
    for key in sorted(set(old_m) & set(new_m)):
        direction = _tracked(key)
        if not direction or old_m[key] == 0:
            continue
        o, n = old_m[key], new_m[key]
        ratio = n / o
        bad = (direction == "higher" and ratio < 1 - threshold) or \
              (direction == "lower" and ratio > 1 + threshold)
        if bad:
            regressions.append({
                "file": path, "metric": key, "direction": direction,
                "old": o, "new": n, "ratio": round(ratio, 4)})
    only_old = sorted(k for k in old_m if k not in new_m and _tracked(k))
    only_new = sorted(k for k in new_m if k not in old_m and _tracked(k))
    if only_old:
        print(f"{path}: {len(only_old)} tracked metric(s) dropped: "
              f"{only_old[:5]}")
    if only_new:
        print(f"{path}: {len(only_new)} tracked metric(s) added")
    return regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--base", default="HEAD~1",
                    help="git revision holding the baseline (default HEAD~1)")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="allowed relative regression (default 0.10 = 10%%)")
    ap.add_argument("--files", nargs="*", default=None,
                    help="explicit BENCH files (default: BENCH_*.json)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="write the regression report as JSON")
    args = ap.parse_args(argv)
    files = args.files if args.files else sorted(glob.glob("BENCH_*.json"))
    if not files:
        print("no BENCH_*.json snapshots found — nothing to diff")
        return 0
    all_reg = []
    for path in files:
        all_reg.extend(diff_file(path, args.base, args.threshold))
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"base": args.base, "threshold": args.threshold,
                       "regressions": all_reg}, fh, indent=2)
    if all_reg:
        print(f"\n{len(all_reg)} regression(s) beyond "
              f"{args.threshold:.0%} vs {args.base}:")
        for r in all_reg:
            arrow = "↓" if r["direction"] == "higher" else "↑"
            print(f"  {r['file']}:{r['metric']}: {r['old']:.4g} -> "
                  f"{r['new']:.4g} ({arrow} x{r['ratio']})")
        return 1
    print(f"no regressions beyond {args.threshold:.0%} vs {args.base} "
          f"across {len(files)} snapshot(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
