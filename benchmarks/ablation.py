"""Paper Fig.8: cumulative ablation — engine (head KV) / smart scheduler /
logit budgeting, each toggled on top of the Sparse-dLLM baseline."""
import dataclasses

from repro.configs.base import ServeConfig
from repro.core.baselines import ablation_profiles
from repro.launch.serve import run_serve


def run(quick: bool = True):
    out = []
    base = ServeConfig(max_num_batched_tokens=768, max_num_logits=96,
                       block_size=8, steps_per_block=8, max_seq_len=192,
                       max_slots=10, max_refresh_per_iter=4)
    profiles = ablation_profiles(base)
    wls = ("burst",) if quick else ("livebench", "burst", "osc")
    for wl in wls:
        ref_tput = None
        for name, serve in profiles.items():
            r = _run_with(serve, wl)
            if ref_tput is None:
                ref_tput = max(r["throughput_tok_s"], 1e-9)
            rel = r["throughput_tok_s"] / ref_tput
            out.append((f"ablation/{wl}/{name}",
                        1e6 / max(r["throughput_tok_s"], 1e-9),
                        f"{rel:.2f}x_vs_baseline"))
    out.append(("ablation/claim", 0.0,
                "paper:engine1.76x_sched1.82x_budget1.97x_burst"))
    return out


def _run_with(serve: ServeConfig, wl: str):
    import repro.launch.serve as S

    def patched(arch, system, workload, rps, n, **kw):
        # bypass the profile table: use this exact ServeConfig
        from repro.configs import get_config, reduced
        from repro.core.engine import Engine
        from repro.data.workloads import make_trace, trace_prompts
        import numpy as np
        cfg = reduced(get_config(arch))
        eng = Engine(cfg, serve, seed=0)
        trace = make_trace(workload, n, rps, seed=0, scale=0.12)
        prompts = trace_prompts(trace, cfg.vocab_size, seed=0)
        reqs = []
        for i, (t, p) in enumerate(zip(trace, prompts)):
            gl = max(serve.block_size,
                     min(t.gen_len, serve.max_seq_len - len(p) - serve.block_size))
            pl = min(len(p), serve.max_seq_len - gl - serve.block_size)
            reqs.append(eng.submit(p[:pl], gen_len=gl, arrival=t.arrival, rid=i))
        stats = eng.run(time_scale=0.02)
        lats = np.array([r.latency for r in reqs])
        return dict(throughput_tok_s=stats.throughput,
                    avg_latency=float(lats.mean()))

    return patched("llada-8b", None, wl, 2.0, 8)
