"""Hypothesis compatibility shim.

Property-test modules import ``given``/``settings``/``st`` from here instead
of from ``hypothesis`` directly. When hypothesis is installed the real
library is used unchanged; when it is absent (minimal containers ship only
jax/numpy/pytest) the tests degrade to fixed-seed parametrized sampling —
every ``@given`` test runs ``max_examples`` deterministic draws from the
same strategy ranges, so collection never breaks and the invariants still
get exercised.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:     # fixed-seed fallback
    import functools
    import inspect

    import numpy as np

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 20
    _SEED = 0x5EED

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def sample(self, rng):
            return self._draw(rng)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(len(elements)))])

    st = _St()

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                rng = np.random.default_rng(_SEED)
                for _ in range(n):
                    draw = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*args, **draw, **kwargs)
            wrapper._is_given = True
            # hide the drawn params from pytest's fixture resolution
            sig = inspect.signature(fn)
            keep = [p for name, p in sig.parameters.items()
                    if name not in strategies]
            wrapper.__signature__ = sig.replace(parameters=keep)
            return wrapper
        return deco

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
