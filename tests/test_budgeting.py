"""Logit-Aware Activation Budgeting (C1): measured memory ordering + the
capacity-coupling mechanism the paper's §4.3 claims."""
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.configs.base import ServeConfig
from repro.core.budgeting import (kv_slot_bytes, logit_activation_bytes,
                                  measure_logit_peak, plan_memory)
from repro.core.baselines import size_slots, system_profiles


def test_measured_logit_peak_ordering():
    """XLA-measured temp bytes: monolithic >> chunked; fused stays within
    tile-buffer range of chunked at toy vocab (it wins at production vocab,
    where chunked still holds [max_num_logits, V] f32)."""
    cfg = reduced(ARCHS["llada-8b"], vocab_size=32768, d_model=128)
    serve = ServeConfig(max_num_logits=512, vocab_tile=256)
    peaks = measure_logit_peak(cfg, serve, n_tokens=4096)
    assert peaks["monolithic"] > 4 * peaks["chunked"], peaks
    assert peaks["fused"] < peaks["chunked"], peaks


def test_paper_example_arithmetic():
    """§3.2: LLaDA-8B, B=16, L=2048, V=126464 -> ~8.3 GB monolithic (fp16;
    our accounting is f32 post-softcap, so 2x)."""
    cfg = get_config("llada-8b")
    serve = ServeConfig(logit_mode="monolithic")
    n = 16 * 2048
    bytes_f32 = logit_activation_bytes(cfg, serve, n)
    assert abs(bytes_f32 / 2 - 8.3e9) / 8.3e9 < 0.05


def test_capacity_coupling():
    """Decomposing the logit tensor must buy KV slots (same HBM budget)."""
    cfg = get_config("llada-8b")
    base = ServeConfig(max_num_batched_tokens=4000, max_num_logits=2048,
                       max_seq_len=2048, max_slots=64)
    import dataclasses
    hbm = 48 << 30   # L40S-sized budget (paper's server-grade setting)
    p_mono = plan_memory(cfg, dataclasses.replace(base, logit_mode="monolithic"), hbm)
    p_chunk = plan_memory(cfg, dataclasses.replace(base, logit_mode="chunked"), hbm)
    p_fused = plan_memory(cfg, dataclasses.replace(base, logit_mode="fused"), hbm)
    assert p_chunk.logit_bytes < p_mono.logit_bytes
    assert p_chunk.kv_pool_bytes > p_mono.kv_pool_bytes
    assert p_fused.kv_pool_bytes >= p_chunk.kv_pool_bytes
    # the reclaimed activation bytes buy concurrent requests
    assert p_fused.max_slots > p_mono.max_slots


def test_sparse_retention_halves_slot_bytes():
    cfg = get_config("llada-8b")
    import dataclasses
    s_full = ServeConfig(max_seq_len=2048, retention_ratio=1.0)
    s_half = dataclasses.replace(s_full, retention_ratio=0.5)
    assert kv_slot_bytes(cfg, s_half) < 0.6 * kv_slot_bytes(cfg, s_full)


def test_system_profiles_capacity_ordering():
    """dLLM-Serve's profile must fit at least as many slots as every
    baseline under the same budget (the Table 1 capacity story)."""
    cfg = get_config("llada-8b")
    base = ServeConfig(max_num_batched_tokens=4000, max_num_logits=2048,
                       max_seq_len=2048, max_slots=64)
    hbm = 24 << 30
    slots = {name: size_slots(cfg, s, hbm).max_slots
             for name, s in system_profiles(base).items()}
    assert slots["dllm-serve"] >= max(
        slots["fast-dllm"], slots["dllm-cache"], slots["sparse-dllm"]), slots
    assert slots["dllm-serve"] > slots["fast-dllm"], slots
