"""Shared-prefix KV pool + int8 slot storage (docs/memory.md).

Three layers of proof, mirroring the contract's three claims:

* **Ledger properties** — hypothesis-driven random interleavings of
  record_write/release against a pure-python model store: no leaked or
  double-freed content, refcounts never negative, promotes only ever copy
  live bytes to a live referrer, and a simulated "device" driven only by
  the ledger's (do_write, promote) outputs always serves every logical
  slot its correct content.
* **Pool integration** — deterministic COW sequences against a real KVPool
  with a tiny cache tree: dedup'd writes share one physical row, divergence
  promotes before the new bytes land, free-while-shared never tears.
* **End-to-end bit-identity** — the shared-prefix trace served with
  sharing ON is bit-identical (token ids + conserved EngineStats) to
  sharing OFF across padded/packed × attention/SSM, with dedup hits
  actually observed (a vacuous pass is a failure).

int8 storage: per-dtype round-trip error bounds (the documented tolerance
policy), packed-vs-padded agreement under quantized serving, and the
``plan_memory`` capacity lifts for both multipliers.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.configs import ARCHS, get_config, reduced
from repro.configs.base import ServeConfig
from repro.core.budgeting import kv_slot_bytes, plan_memory
from repro.core.engine import Engine
from repro.core.kv_pool import KVPool
from repro.core.request import State
from repro.core.share_ledger import ShareLedger, block_chain_key, content_key
from repro.data.workloads import PrefixSpec, make_trace, trace_prompts
from repro.kernels import kv_quant as KQ
from repro.kernels import ops as OPS
from repro.models.sparse_select import PackedKV

SERVE = ServeConfig(max_num_batched_tokens=512, max_num_logits=64,
                    block_size=8, steps_per_block=8, max_seq_len=128,
                    max_slots=6, max_refresh_per_iter=2,
                    selection="head", scheduler="phase", logit_mode="chunked",
                    varlen_pack=True, token_bucket=64)


# ---------------------------------------------------------------------------
# content keys
# ---------------------------------------------------------------------------

def test_block_chain_key_is_prefix_chain():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 100, 64).astype(np.int32)
    b = a.copy()
    assert block_chain_key(a, 8) == block_chain_key(b, 8)
    b[-1] += 1                      # divergence in the LAST block only
    assert block_chain_key(a, 8) != block_chain_key(b, 8)
    # the chain property: equal prefixes hash equal at every block boundary
    assert block_chain_key(a[:32], 8) == block_chain_key(b[:32], 8)


def test_content_key_covers_geometry_and_frontend():
    t = np.arange(64, dtype=np.int32)
    k0 = content_key(t, 8, 64, 32, None)
    assert content_key(t, 8, 64, 32, None) == k0
    assert content_key(t, 8, 60, 32, None) != k0        # total_len differs
    assert content_key(t, 8, 64, 40, None) != k0        # block_start differs
    fe = np.ones((2, 4), np.float32)
    kf = content_key(t, 8, 64, 32, fe)
    assert kf != k0
    assert content_key(t, 8, 64, 32, fe * 2) != kf      # frontend content


# ---------------------------------------------------------------------------
# ledger properties (hypothesis interleavings vs a model store)
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10**9), n_slots=st.integers(2, 8),
       n_keys=st.integers(1, 5))
def test_ledger_random_interleavings(seed, n_slots, n_keys):
    """Drive 200 random write/release ops; after every op the ledger's full
    invariant suite holds and a simulated device — mutated ONLY as the
    ledger's outputs dictate — serves every logical slot its true content."""
    rng = np.random.default_rng(seed)
    led = ShareLedger()
    model = {}       # logical slot -> content key it should read
    phys = {}        # physical row -> key actually stored on "device"
    for _ in range(200):
        slot = int(rng.integers(0, n_slots))
        if rng.integers(0, 3) < 2:                       # write
            key = bytes([int(rng.integers(0, n_keys))])
            before = dict(model)
            do_write, promote = led.record_write(slot, key)
            if promote is not None:
                src, dst = promote
                # promote law: dst was a live referrer of src's old content
                assert before.get(dst) == before.get(slot)
                assert dst != slot and dst in model
                phys[dst] = phys[src]
            if do_write:
                phys[slot] = key
            model[slot] = key
        else:                                            # release
            promote = led.release(slot)
            if promote is not None:
                src, dst = promote
                assert model.get(dst) == model.get(slot)
                phys[dst] = phys[src]
            model.pop(slot, None)
        led.check()
        assert set(led.owner_of) == set(model)
        assert led.phys_slots == len(set(model.values()))
        for s, k in model.items():
            assert led.refcount(led.resolve(s)) >= 1
            assert phys[led.resolve(s)] == k, \
                f"slot {s} would gather stale bytes"
        for s in range(n_slots):
            assert led.refcount(s) >= 0


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10**9), n_slots=st.integers(2, 6))
def test_ledger_generation_monotonic_under_pool(seed, n_slots):
    """Pool-level interleavings of take/write_shared/free: slot generations
    only ever grow, no content leaks past the last release, and double-free
    still raises with the ledger in the loop."""
    rng = np.random.default_rng(seed)
    pool = KVPool(n_slots, sharing=True)
    gens = np.zeros(n_slots, np.int64)
    held = set()
    cache = {"x": jnp.zeros((2, 1, 4), jnp.float32)}
    for _ in range(80):
        op = int(rng.integers(0, 3))
        slot = int(rng.integers(0, n_slots))
        if op == 0 and slot not in held:
            g = pool.take(slot)
            assert g >= gens[slot]
            gens[slot] = g
            held.add(slot)
        elif op == 1 and held:
            s = sorted(held)[int(rng.integers(0, len(held)))]
            key = bytes([int(rng.integers(0, 3))])
            pool.write_shared([s], cache, [key])
        elif op == 2 and held:
            s = sorted(held)[int(rng.integers(0, len(held)))]
            pool.free([s])
            held.discard(s)
            with pytest.raises(RuntimeError):
                pool.free([s])
            assert pool.generation(s) > gens[s]
            gens[s] = pool.generation(s)
        pool.ledger.check()
        assert pool.phys_slots_in_use <= len(held)
    pool.free(sorted(held))
    assert pool.slots_in_use == [] and pool.phys_slots_in_use == 0


# ---------------------------------------------------------------------------
# pool integration (deterministic COW sequences)
# ---------------------------------------------------------------------------

def _tiny_cache(val: float):
    return {"kv": jnp.full((2, 1, 3), val, jnp.float32)}


def _row(pool, slot):
    return np.asarray(pool.gather([slot])["kv"])[:, 0]


def test_pool_dedup_shares_one_row_and_cow_promotes():
    pool = KVPool(4, sharing=True)
    for s in (0, 1, 2):
        pool.take(s)
    pool.write_shared([0], _tiny_cache(1.0), [b"A"])
    pool.write_shared([1], _tiny_cache(1.0), [b"A"])     # dedup hit
    pool.write_shared([2], _tiny_cache(2.0), [b"B"])
    assert pool.ledger.hits == 1
    assert pool.phys_slots_in_use == 2                   # A + B
    assert np.all(_row(pool, 0) == 1.0) and np.all(_row(pool, 1) == 1.0)
    # slot 0 (the owner of A) diverges: its bytes must survive on slot 1
    pool.write_shared([0], _tiny_cache(3.0), [b"C"])
    assert pool.ledger.cow_promotes == 1
    assert np.all(_row(pool, 0) == 3.0)
    assert np.all(_row(pool, 1) == 1.0), "referrer lost its bytes to COW"
    # free-while-shared: re-share then free the owner; referrer keeps bytes
    pool.write_shared([0], _tiny_cache(2.0), [b"B"])     # join slot 2's B
    assert pool.ledger.hits == 2
    pool.free([2])                                       # owner of B dies
    assert np.all(_row(pool, 0) == 2.0), "promote-on-release tore content"
    pool.free([0, 1])
    assert pool.phys_slots_in_use == 0 and pool.slots_in_use == []


def test_pool_write_shared_requires_sharing():
    pool = KVPool(2)
    with pytest.raises(RuntimeError, match="sharing"):
        pool.write_shared([0], _tiny_cache(1.0), [b"A"])


def test_pool_rejects_quant_with_mesh_and_bad_mode():
    with pytest.raises(ValueError):
        KVPool(2, kv_quant="int4")
    with pytest.raises(NotImplementedError):
        KVPool(2, shardings={"x": None}, kv_quant="int8")


# ---------------------------------------------------------------------------
# int8 storage: round-trip bounds + quantized pool
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int8_roundtrip_error_bound(dtype):
    """|x - dq(q(x))| <= roundtrip_bound(per-slot absmax, dtype) — the
    documented tolerance policy (docs/memory.md), checked leaf-wise over
    random caches with per-slot dynamic ranges spanning 4 decades."""
    rng = np.random.default_rng(7)
    L, B, H, T = 2, 5, 3, 4
    scales = 10.0 ** rng.uniform(-2, 2, (L, B))
    x = (rng.standard_normal((L, B, H, T)) * scales[..., None, None])
    kv = jnp.asarray(x, dtype)
    cache = PackedKV(k=kv, v=kv,
                     pos=jnp.zeros((L, B, H), jnp.int32),
                     valid=jnp.ones((L, B, H), jnp.bool_))
    q, sc = KQ.quantize_slot_leaves(cache)
    assert q.k.dtype == jnp.int8 and q.pos.dtype == jnp.int32
    dtypes = {i: dtype for i in sc}
    dq = KQ.dequantize_slot_leaves(q, sc, dtypes)
    xf = np.asarray(kv, np.float32)          # storage-visible values
    absmax = np.abs(xf).max(axis=(2, 3))
    err = np.abs(np.asarray(dq.k, np.float32) - xf)
    bound = np.vectorize(lambda a: KQ.roundtrip_bound(a, dtype))(absmax)
    assert np.all(err.max(axis=(2, 3)) <= bound + 1e-9), \
        (err.max(axis=(2, 3)) / bound).max()
    # pos/valid leaves pass through untouched
    assert np.array_equal(np.asarray(dq.pos), np.asarray(cache.pos))


def test_dequantize_gathered_is_identity_without_quant():
    g = {"anything": jnp.ones((2, 2))}
    assert OPS.dequantize_gathered(g, "none", None) is g


def test_quant_mask_selects_only_kv_leaves():
    cache = {"kv": PackedKV(k=1.0, v=2.0, pos=3, valid=True),
             "ssm_state": jnp.zeros((2, 1, 4), jnp.float32)}
    flags = KQ.quant_leaf_flags(cache)
    leaves = jax.tree.leaves(KQ.quant_mask(cache))
    assert flags == leaves
    assert sum(flags) == 2                  # k and v only, never SSM state


def test_quantized_pool_roundtrips_through_gather():
    pool = KVPool(3, kv_quant="int8")
    kv = jnp.asarray(np.linspace(-2, 2, 2 * 1 * 4).reshape(2, 1, 4),
                     jnp.float32)
    cache = PackedKV(k=kv, v=kv * 0.5,
                     pos=jnp.zeros((2, 1), jnp.int32),
                     valid=jnp.ones((2, 1), jnp.bool_))
    pool.take(1)
    pool.write(
        [1], cache)
    g = pool.gather([1])
    assert set(g) == {"data", "scale"}
    dq = OPS.dequantize_gathered(g, "int8", pool.gathered_dtypes)
    bound = KQ.roundtrip_bound(2.0, jnp.float32)
    assert np.abs(np.asarray(dq.k)[:, 0] - np.asarray(kv)[:, 0]).max() \
        <= bound
    assert np.array_equal(np.asarray(dq.pos), np.asarray(cache.pos))


# ---------------------------------------------------------------------------
# plan_memory capacity lifts
# ---------------------------------------------------------------------------

def test_plan_memory_int8_strictly_more_slots():
    cfg = get_config("llada-8b")
    s = ServeConfig(max_num_batched_tokens=4000, max_num_logits=2048,
                    max_seq_len=2048, max_slots=4096, logit_mode="chunked")
    hbm = 48 << 30
    p0 = plan_memory(cfg, s, hbm)
    pq = plan_memory(cfg, dataclasses.replace(s, kv_quant="int8"), hbm)
    assert kv_slot_bytes(cfg, dataclasses.replace(s, kv_quant="int8")) < \
        kv_slot_bytes(cfg, s)
    assert pq.max_slots > p0.max_slots
    assert pq.phys_slots > p0.phys_slots     # int8 grows PHYSICAL capacity
    assert "int8" in pq.summary()


def test_plan_memory_sharing_at_least_doubles_slots():
    """The acceptance criterion: at equal HBM, sharing ON with the
    shared-prefix trace's measured share factor plans >= 2x the slots of
    sharing OFF — as LOGICAL capacity; physical capacity is unchanged
    (the reserved-backing pool allocates physical rows only)."""
    from repro.data.workloads import prefix_share_factor
    trace = make_trace("shared-prefix", 64, rps=4.0, seed=0,
                       prefix=PrefixSpec(n_prefixes=4, prefix_len=64))
    share = prefix_share_factor(trace)
    assert share >= 2.0                      # 64 reqs over <= 4x few groups
    cfg = get_config("llada-8b")
    s = ServeConfig(max_num_batched_tokens=4000, max_num_logits=2048,
                    max_seq_len=2048, max_slots=4096, logit_mode="chunked")
    hbm = 48 << 30
    p_off = plan_memory(cfg, s, hbm)
    p_on = plan_memory(cfg, dataclasses.replace(s, prefix_sharing=True),
                       hbm, share_factor=share)
    assert p_on.max_slots >= 2 * p_off.max_slots
    assert p_on.phys_slots == p_off.max_slots
    # share_factor without the flag must be inert (ternary, not a branch)
    p_flag_off = plan_memory(cfg, s, hbm, share_factor=share)
    assert p_flag_off.max_slots == p_off.max_slots


# ---------------------------------------------------------------------------
# end-to-end bit-identity: sharing ON == OFF
# ---------------------------------------------------------------------------

E2E_COUNTERS = ("committed_tokens", "iterations", "refresh_steps",
                "reuse_steps", "refresh_tokens_real", "reuse_tokens_real",
                "logit_tokens_real", "preemptions")


def _serve_shared_trace(arch, varlen, sharing, kv_quant="none", n=8):
    cfg = reduced(ARCHS[arch])
    serve = dataclasses.replace(SERVE, varlen_pack=varlen,
                                prefix_sharing=sharing, kv_quant=kv_quant,
                                preempt_starvation_s=0.05)
    eng = Engine(cfg, serve, seed=0, clock="modeled")
    trace = make_trace("shared-prefix", n, rps=8.0, seed=3,
                       prefix=PrefixSpec(n_prefixes=3, prefix_len=24))
    prompts = trace_prompts(trace, cfg.vocab_size, seed=3)
    # arrival 0 for everyone: co-resident duplicates are what the
    # slot-granular ledger can dedup (requests that arrive after their
    # twin has advanced past block 0 share nothing — docs/memory.md), and
    # with max_slots < n the starvation preemption path runs under sharing
    reqs = [eng.submit(p, gen_len=16, arrival=0.0, rid=i)
            for i, (t, p) in enumerate(zip(trace, prompts))]
    stats = eng.run()
    return eng, reqs, stats


@pytest.mark.parametrize("arch", ["llada-8b", "mamba2-130m"])
@pytest.mark.parametrize("varlen", [True, False])
def test_e2e_sharing_bit_identical(arch, varlen):
    """Sharing is a pure storage optimization: ON and OFF runs of the
    shared-prefix trace agree on every token id and every scheduling
    counter, on the packed engine AND the padded oracle, for attention and
    SSM state alike — and the ON run actually dedups (non-vacuous)."""
    _, r_off, s_off = _serve_shared_trace(arch, varlen, sharing=False)
    eng, r_on, s_on = _serve_shared_trace(arch, varlen, sharing=True)
    assert s_on.shared_hits > 0, "trace produced no sharing — vacuous test"
    assert s_on.conserved() and s_off.conserved()
    for name in E2E_COUNTERS:
        assert getattr(s_on, name) == getattr(s_off, name), name
    for a, b in zip(r_off, r_on):
        assert a.state == b.state
        if a.state == State.FINISHED:
            assert np.array_equal(a.output_tokens(), b.output_tokens()), \
                f"rid {a.rid} diverged under sharing"
    # all references released at drain; peak physical occupancy beat the
    # logical resident count (the footprint claim, measured not planned)
    assert eng.pool.slots_in_use == [] and eng.pool.phys_slots_in_use == 0
    assert 0 < s_on.phys_slots_peak <= SERVE.max_slots
    eng.pool.ledger.check()


def test_e2e_int8_packed_matches_padded():
    """Packed-vs-padded agreement under quantized serving: both paths
    read the SAME int8 pool through the same dequant law, so at this scale
    (confidence margins >> one quantization step; docs/memory.md tolerance
    policy) token ids stay exactly equal."""
    _, r_pad, s_pad = _serve_shared_trace("llada-8b", False, False, "int8")
    _, r_pk, s_pk = _serve_shared_trace("llada-8b", True, False, "int8")
    assert s_pad.conserved() and s_pk.conserved()
    for a, b in zip(r_pad, r_pk):
        assert np.array_equal(a.output_tokens(), b.output_tokens())


def test_e2e_sharing_composes_with_int8():
    _, r_off, s_off = _serve_shared_trace("llada-8b", True, False, "int8")
    _, r_on, s_on = _serve_shared_trace("llada-8b", True, True, "int8")
    assert s_on.shared_hits > 0
    for a, b in zip(r_off, r_on):
        assert np.array_equal(a.output_tokens(), b.output_tokens())


def test_engine_rejects_bad_quant_and_quant_mesh():
    cfg = reduced(ARCHS["llada-8b"])
    with pytest.raises(ValueError):
        Engine(cfg, dataclasses.replace(SERVE, kv_quant="fp4"), seed=0)
    with pytest.raises(NotImplementedError):
        Engine(cfg, dataclasses.replace(SERVE, kv_quant="int8",
                                        mesh_shape=(1, 1)), seed=0)
