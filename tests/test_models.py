"""Per-arch smoke tests: reduced same-family config, one forward + one train
step on CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED, reduced
from repro.configs.base import TrainConfig
from repro.models import backbone as BB
from repro.models import transformer as T
from repro.train.optimizer import init_opt_state
from repro.train.train_loop import make_train_step

KEY = jax.random.PRNGKey(0)
SERVE = T.ServeContext(block_size=8, retain=16, q_chunk=16)


def _inputs(cfg, B=2, S=64):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    fe = (jax.random.normal(KEY, (B, cfg.frontend_len, cfg.frontend_dim))
          if cfg.frontend_dim else None)
    return tokens, fe


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_forward_shapes_no_nan(arch):
    cfg = reduced(ARCHS[arch])
    params = BB.init_params(cfg, KEY)
    tokens, fe = _inputs(cfg)
    h, aux = BB.train_forward(params, cfg, tokens, fe, remat=False)
    S_tot = tokens.shape[1] + (cfg.frontend_len if cfg.frontend_dim else 0)
    assert h.shape == (2, S_tot, cfg.d_model)
    assert not np.any(np.isnan(np.asarray(h, np.float32)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_train_step_no_nan(arch):
    cfg = reduced(ARCHS[arch])
    tc = TrainConfig(microbatches=2, loss_chunk=64, remat=True,
                     warmup_steps=2)
    params = BB.init_params(cfg, KEY)
    opt = init_opt_state(params)
    tokens, fe = _inputs(cfg, B=4, S=32)
    step = make_train_step(cfg, tc, total_steps=10)
    args = (params, opt, tokens, jax.random.PRNGKey(1))
    if cfg.frontend_dim:
        fe4 = jax.random.normal(KEY, (4, cfg.frontend_len, cfg.frontend_dim))
        args = args + (fe4,)
    params2, opt2, m = jax.jit(step)(*args)
    assert np.isfinite(float(m["loss"])), arch
    assert np.isfinite(float(m["grad_norm"])), arch
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                - b.astype(jnp.float32)).sum()),
                     params, params2))
    assert delta > 0, arch


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_serve_refresh_reuse_shapes(arch):
    cfg = reduced(ARCHS[arch])
    params = BB.init_params(cfg, KEY)
    tokens, fe = _inputs(cfg)
    bs = jnp.array([8, 16], dtype=jnp.int32)
    out = BB.serve_refresh(params, cfg, tokens, bs, SERVE, fe)
    assert out.block_hidden.shape == (2, 8, cfg.d_model)
    bpos = bs[:, None] + jnp.arange(8)[None]
    btoks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    hb = BB.serve_reuse(params, cfg, btoks, bpos, out.cache, SERVE)
    assert hb.shape == (2, 8, cfg.d_model)
    assert not np.any(np.isnan(np.asarray(hb, np.float32)))


def test_gemma2_softcap_active():
    cfg = reduced(ARCHS["gemma2-27b"])
    assert cfg.attn_softcap and cfg.final_softcap
    from repro.models import lm_head as LM
    params = BB.init_params(cfg, KEY)
    h = jax.random.normal(KEY, (4, cfg.d_model)) * 100.0
    z = LM.logits_monolithic(params["embed"], cfg, h)
    assert float(jnp.abs(z).max()) <= cfg.final_softcap + 1e-3


def test_ssd_chunked_equals_sequential():
    """Mamba2 SSD chunked scan == step-by-step recurrence."""
    from repro.models.ssm import ssd_scan
    B, S, H, P, N, chunk = 2, 40, 3, 4, 5, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    init = jax.random.normal(KEY, (B, H, P, N))
    y, fs = ssd_scan(x, dt, A, Bm, Cm, chunk, init)
    state = init.astype(jnp.float32)
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A[None])
        state = state * dA[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], Bm[:, t], x[:, t])
        ys.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t], state))
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.stack(ys, 1)),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(state), atol=1e-4)


def test_ssm_refresh_reuse_consistency():
    """Reuse-phase recurrent decode from the captured state must match the
    full forward's hidden states for the same block (causal model)."""
    cfg = reduced(ARCHS["mamba2-130m"])
    params = BB.init_params(cfg, KEY)
    B, S = 1, 64
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    bs = jnp.array([16], dtype=jnp.int32)
    out = BB.serve_refresh(params, cfg, tokens, bs, SERVE)
    bpos = bs[:, None] + jnp.arange(8)[None]
    btoks = jax.lax.dynamic_slice_in_dim(tokens, 16, 8, axis=1)
    hb = BB.serve_reuse(params, cfg, btoks, bpos, out.cache, SERVE)
    np.testing.assert_allclose(np.asarray(hb), np.asarray(out.block_hidden),
                               atol=2e-3)
