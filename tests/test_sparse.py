"""Head-centric sparse KV selection (C3): correctness + properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.configs import ARCHS, reduced
from repro.models import backbone as BB
from repro.models import transformer as T
from repro.models.sparse_select import (head_scores, pack, select_and_pack,
                                        select_indices)

KEY = jax.random.PRNGKey(3)


def test_full_retention_equals_dense():
    """selection='none' with retain == everything-outside-the-block must give
    byte-identical reuse attention to recomputing over the full context."""
    cfg = reduced(ARCHS["llada-8b"])
    params = BB.init_params(cfg, KEY)
    B, S, Sb = 2, 64, 8
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    bs = jnp.array([24, 40], dtype=jnp.int32)
    dense_ctx = T.ServeContext(block_size=Sb, retain=S - Sb,
                               selection="none", q_chunk=S)
    out = BB.serve_refresh(params, cfg, tokens, bs, dense_ctx)
    # reuse with the SAME block tokens -> hidden must equal refresh's block
    btoks = jax.vmap(lambda t, s: jax.lax.dynamic_slice_in_dim(t, s, Sb))(
        tokens, bs)
    bpos = bs[:, None] + jnp.arange(Sb)[None]
    hb = BB.serve_reuse(params, cfg, btoks, bpos, out.cache, dense_ctx)
    np.testing.assert_allclose(np.asarray(hb, np.float32),
                               np.asarray(out.block_hidden, np.float32),
                               atol=2e-3)


def test_head_vs_uniform_indices_differ():
    B, Sb, K, G, S, dh = 1, 4, 4, 2, 64, 8
    q = jax.random.normal(KEY, (B, Sb, K * G, dh))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, K, dh))
    scores = head_scores(q, k, kernel_size=3)
    excl = jnp.zeros((B, S), bool)
    ih = select_indices(scores, 8, mode="head", exclude=excl)
    iu = select_indices(scores, 8, mode="uniform", exclude=excl)
    # uniform: all heads share one set
    assert np.all(np.asarray(iu) == np.asarray(iu)[:, :1])
    # head: at least one head deviates (random data)
    assert not np.all(np.asarray(ih) == np.asarray(ih)[:, :1])


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 999), retain=st.integers(1, 16),
       mode=st.sampled_from(["head", "uniform"]))
def test_pack_property(seed, retain, mode):
    """Packed cache entries must be exact copies of the selected tokens'
    K/V, and selected indices must avoid excluded positions when possible."""
    r = jax.random.PRNGKey(seed)
    B, Sb, K, G, S, dh = 1, 2, 2, 2, 24, 4
    ks = jax.random.split(r, 4)
    q = jax.random.normal(ks[0], (B, Sb, K * G, dh))
    kf = jax.random.normal(ks[1], (B, S, K, dh))
    vf = jax.random.normal(ks[2], (B, S, K, dh))
    excl = jnp.zeros((B, S), bool).at[:, 4:8].set(True)
    packed = select_and_pack(q, kf, vf, retain=retain, kernel_size=3,
                             mode=mode, exclude=excl,
                             token_valid=jnp.ones((B, S), bool))
    idx = np.asarray(packed.pos)
    kh = np.asarray(kf.transpose(0, 2, 1, 3))
    vh = np.asarray(vf.transpose(0, 2, 1, 3))
    for b in range(B):
        for h in range(K):
            np.testing.assert_allclose(np.asarray(packed.k)[b, h],
                                       kh[b, h, idx[b, h]], atol=0)
            np.testing.assert_allclose(np.asarray(packed.v)[b, h],
                                       vh[b, h, idx[b, h]], atol=0)
            # indices sorted (sequence order preserved)
            assert np.all(np.diff(idx[b, h]) >= 0)
    # excluded positions are marked invalid
    val = np.asarray(packed.valid)
    for b in range(B):
        for h in range(K):
            in_excl = (idx[b, h] >= 4) & (idx[b, h] < 8)
            assert not np.any(val[b, h][in_excl])


def test_retention_quality_ordering():
    """Head-centric selection approximates dense attention at least as well
    as uniform at equal retention (attention-output fidelity proxy, the
    basis of benchmark fig6)."""
    cfg = reduced(ARCHS["llada-8b"])
    params = BB.init_params(cfg, KEY)
    B, S, Sb = 2, 96, 8
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    bs = jnp.array([48, 64], dtype=jnp.int32)
    btoks = jax.vmap(lambda t, s: jax.lax.dynamic_slice_in_dim(t, s, Sb))(
        tokens, bs)
    bpos = bs[:, None] + jnp.arange(Sb)[None]

    def reuse_err(selection, retain):
        ctx = T.ServeContext(block_size=Sb, retain=retain,
                             selection=selection, q_chunk=S)
        out = BB.serve_refresh(params, cfg, tokens, bs, ctx)
        hb = BB.serve_reuse(params, cfg, btoks, bpos, out.cache, ctx)
        dense = T.ServeContext(block_size=Sb, retain=S - Sb,
                               selection="none", q_chunk=S)
        outd = BB.serve_refresh(params, cfg, tokens, bs, dense)
        hd = BB.serve_reuse(params, cfg, btoks, bpos, outd.cache, dense)
        return float(jnp.mean(jnp.abs(hb - hd)))

    e_head = reuse_err("head", 24)
    e_unif = reuse_err("uniform", 24)
    assert e_head <= e_unif * 1.25, (e_head, e_unif)
