"""Mesh-serving agreement suite (the sharding oracle).

Two anchors:
  * in-process: a 1×1-mesh engine must be BIT-identical to the no-mesh
    engine — device_put to a one-device mesh and the sharded jit wrappers
    are numerically transparent, so every padded-vs-packed oracle keeps
    holding on the single-device path.
  * subprocess (2 CPU host devices, same precedent as the dry-run cells):
    ``launch/shard_check.py`` serves the same trace unsharded and on a
    ``REPRO_MESH=1,2`` mesh and demands matching committed token ids,
    captured slot-pool caches, and EngineStats token counters — for an
    attention arch and an SSM arch, with the jnp paths AND with the Pallas
    hot paths shard_mapped per shard (``--kernels``), plus a ``(2, 1)``
    data-axis mesh exercising the slot pool sharded over ``data``.
"""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.base import ServeConfig
from repro.core.engine import Engine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ,
           PYTHONPATH=os.path.join(REPO, "src"),
           REPRO_XLA_FLAGS="--xla_force_host_platform_device_count=2",
           REPRO_MESH="1,2")

BASE = ServeConfig(max_num_batched_tokens=512, max_num_logits=64,
                   block_size=8, steps_per_block=8, max_seq_len=128,
                   max_slots=8, max_refresh_per_iter=2,
                   logit_mode="chunked", varlen_pack=True, token_bucket=64)


def _serve(serve, arch="llada-8b", n=4, seed=0):
    cfg = reduced(ARCHS[arch])
    eng = Engine(cfg, serve, seed=seed)
    rng = np.random.default_rng(seed)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size - 1,
                                    int(rng.integers(8, 40))),
                       gen_len=16, arrival=0.0, rid=i) for i in range(n)]
    stats = eng.run()
    return eng, reqs, stats


def test_1x1_mesh_bit_identical_to_no_mesh():
    import jax

    from repro.models import layers as Lmod
    saved = dict(Lmod._SHARDING_POLICY)
    try:
        eng0, r0, st0 = _serve(BASE)
        eng1, r1, st1 = _serve(dataclasses.replace(BASE, mesh_shape=(1, 1)))
        assert eng1.mesh_devices == 1
        for a, b in zip(r0, r1):
            assert np.array_equal(a.output_tokens(), b.output_tokens())
        assert st0.committed_tokens == st1.committed_tokens
        assert st0.refresh_tokens_exec == st1.refresh_tokens_exec
        for la, lb in zip(jax.tree.leaves(jax.device_get(eng0.pool.cache)),
                          jax.tree.leaves(jax.device_get(eng1.pool.cache))):
            assert np.array_equal(np.asarray(la), np.asarray(lb))
    finally:
        # the mesh engine installs a global serving policy — restore so later
        # (policy-free) tests in this process see the state they started with
        Lmod.set_sharding_policy(saved)


def test_1x1_mesh_bit_identical_with_kernels():
    """The bit-identity law must also hold with the Pallas hot paths live:
    a 1-sized model axis skips shard_map entirely (kernels.ops dispatches
    the identical local call), so 1×1-mesh == no-mesh byte for byte."""
    import jax

    from repro.models import layers as Lmod
    saved = dict(Lmod._SHARDING_POLICY)
    kbase = dataclasses.replace(BASE, use_flash_kernel=True,
                                logit_mode="fused")
    try:
        eng0, r0, st0 = _serve(kbase)
        eng1, r1, st1 = _serve(dataclasses.replace(kbase, mesh_shape=(1, 1)))
        assert eng1.mesh_devices == 1
        assert eng1.kernels_active
        for a, b in zip(r0, r1):
            assert np.array_equal(a.output_tokens(), b.output_tokens())
        assert st0.committed_tokens == st1.committed_tokens
        for la, lb in zip(jax.tree.leaves(jax.device_get(eng0.pool.cache)),
                          jax.tree.leaves(jax.device_get(eng1.pool.cache))):
            assert np.array_equal(np.asarray(la), np.asarray(lb))
    finally:
        Lmod.set_sharding_policy(saved)


def test_mesh_engine_rejects_indivisible_kernel_dims():
    """The old blanket mesh×kernels rejection is gone; what remains is the
    fail-loud divisibility law — validated BEFORE the mesh is built, so no
    3-device host is needed. Reduced llada has 4 heads / vocab 256: both
    indivisible by a 3-way model axis."""
    cfg = reduced(ARCHS["llada-8b"])
    with pytest.raises(ValueError, match="Pallas.*divide"):
        Engine(cfg, dataclasses.replace(BASE, mesh_shape=(1, 3),
                                        use_flash_kernel=True,
                                        logit_mode="fused"))
    # jnp paths on the same mesh shape carry no kernel divisibility law:
    # construction must get past kernel validation to the mesh build
    # (which then fails for lack of 3 devices — a different, device error)
    with pytest.raises(Exception) as ei:
        Engine(cfg, dataclasses.replace(BASE, mesh_shape=(1, 3)))
    assert "Pallas" not in str(ei.value)


@pytest.mark.parametrize("arch,extra", [
    ("llada-8b", ["--warmup"]),      # attention stream + sharded AOT warmup
    ("mamba2-130m", []),             # segment-reset SSD scan
    # Pallas hot paths per-shard: head-sharded varlen attention + fused
    # vocab-sharded argmax, SSD scan over state heads — vs the 1-device
    # kernel run (token ids bit-identical)
    ("llada-8b", ["--kernels"]),
    ("mamba2-130m", ["--kernels"]),
    # data-axis mesh: slot pool sharded over 'data' (padded slot axis),
    # replica streams serve the same trace bit-identically
    ("llada-8b", ["--kernels", "--mesh", "2,1"]),
    # refcounted prefix sharing over duplicated prompts: dedup hits, COW
    # promotes, and the promote-on-release target choice must be
    # device-count invariant (1-device run == 2-device mesh run)
    ("llada-8b", ["--sharing", "--n", "6"]),
])
def test_shard_agreement_subprocess(arch, extra, tmp_path):
    out = tmp_path / "agree.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.shard_check", "--arch", arch,
         "--out", str(out)] + extra,
        capture_output=True, text=True, env=ENV, cwd=REPO, timeout=420)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.loads(out.read_text())
    assert rec["ok"], rec
    assert rec["mesh_devices"] == 2, rec
    if "--sharing" in extra:
        # shard_check itself fails on zero hits, but pin it here too:
        # a vacuous agreement run must never count as coverage
        assert rec["shared_hits"] > 0, rec
