"""End-to-end engine behaviour (tiny model, real execution on CPU)."""
import dataclasses

import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.base import ServeConfig
from repro.core.engine import Engine
from repro.core.request import State

BASE = ServeConfig(max_num_batched_tokens=512, max_num_logits=64,
                   block_size=8, steps_per_block=8, max_seq_len=128,
                   max_slots=8, max_refresh_per_iter=2,
                   selection="head", scheduler="phase", logit_mode="chunked")


def serve_some(serve, n=5, seed=0, arch="llada-8b", gen_len=16):
    cfg = reduced(ARCHS[arch])
    eng = Engine(cfg, serve, seed=seed)
    rng = np.random.default_rng(seed)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size - 1,
                                    int(rng.integers(8, 40))),
                       gen_len=gen_len, arrival=0.0, rid=i)
            for i in range(n)]
    stats = eng.run()
    return eng, reqs, stats


def test_all_requests_finish_and_unmask():
    eng, reqs, stats = serve_some(BASE)
    for r in reqs:
        assert r.state == State.FINISHED
        assert (r.output_tokens() != eng.mask_id).all()
        assert r.latency > 0
    assert stats.committed_tokens == sum(r.gen_len for r in reqs)
    assert stats.refresh_steps > 0 and stats.reuse_steps > 0


def test_budget_invariant_holds_live():
    eng, reqs, stats = serve_some(BASE, n=7)
    assert stats.peak_query_tokens <= BASE.max_num_batched_tokens


def test_deterministic_outputs():
    _, r1, _ = serve_some(BASE, n=3, seed=42)
    _, r2, _ = serve_some(BASE, n=3, seed=42)
    for a, b in zip(r1, r2):
        assert np.array_equal(a.output_tokens(), b.output_tokens())


def test_logit_modes_equivalent_outputs():
    outs = {}
    for mode in ("monolithic", "chunked", "fused"):
        serve = dataclasses.replace(BASE, logit_mode=mode, vocab_tile=64)
        _, reqs, _ = serve_some(serve, n=3, seed=7)
        outs[mode] = [r.output_tokens().copy() for r in reqs]
    for a, b in zip(outs["monolithic"], outs["chunked"]):
        assert np.array_equal(a, b)
    for a, b in zip(outs["monolithic"], outs["fused"]):
        assert np.array_equal(a, b)


def test_request_scheduler_also_completes():
    serve = dataclasses.replace(BASE, scheduler="request",
                                selection="uniform",
                                logit_mode="monolithic")
    eng, reqs, stats = serve_some(serve, n=5)
    assert all(r.state == State.FINISHED for r in reqs)


def test_flash_kernel_engine_path():
    serve = dataclasses.replace(BASE, use_flash_kernel=True)
    eng, reqs, stats = serve_some(serve, n=3)
    assert all(r.state == State.FINISHED for r in reqs)


def test_kv_pool_isolation():
    """Requests in different slots must not corrupt each other: serving the
    same prompt alone or alongside others yields identical output."""
    cfg = reduced(ARCHS["llada-8b"])
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size - 1, 24)

    eng1 = Engine(cfg, BASE, seed=0)
    r_alone = eng1.submit(prompt, gen_len=16, arrival=0.0, rid=0)
    eng1.run()

    eng2 = Engine(cfg, BASE, seed=0)
    r_multi = eng2.submit(prompt, gen_len=16, arrival=0.0, rid=0)
    for i in range(3):
        eng2.submit(rng.integers(0, cfg.vocab_size - 1, 16),
                    gen_len=16, arrival=0.0, rid=10 + i)
    eng2.run()
    assert np.array_equal(r_alone.output_tokens(), r_multi.output_tokens())


def test_ssm_arch_serves():
    eng, reqs, stats = serve_some(BASE, n=3, arch="mamba2-130m")
    assert all(r.state == State.FINISHED for r in reqs)
