"""End-to-end engine behaviour (tiny model, real execution on CPU)."""
import dataclasses

import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.base import ServeConfig
from repro.core.engine import Engine
from repro.core.request import State

BASE = ServeConfig(max_num_batched_tokens=512, max_num_logits=64,
                   block_size=8, steps_per_block=8, max_seq_len=128,
                   max_slots=8, max_refresh_per_iter=2,
                   selection="head", scheduler="phase", logit_mode="chunked")


def serve_some(serve, n=5, seed=0, arch="llada-8b", gen_len=16):
    cfg = reduced(ARCHS[arch])
    eng = Engine(cfg, serve, seed=seed)
    rng = np.random.default_rng(seed)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size - 1,
                                    int(rng.integers(8, 40))),
                       gen_len=gen_len, arrival=0.0, rid=i)
            for i in range(n)]
    stats = eng.run()
    return eng, reqs, stats


def test_all_requests_finish_and_unmask():
    eng, reqs, stats = serve_some(BASE)
    for r in reqs:
        assert r.state == State.FINISHED
        assert (r.output_tokens() != eng.mask_id).all()
        assert r.latency > 0
    assert stats.committed_tokens == sum(r.gen_len for r in reqs)
    assert stats.refresh_steps > 0 and stats.reuse_steps > 0


def test_budget_invariant_holds_live():
    eng, reqs, stats = serve_some(BASE, n=7)
    assert stats.peak_query_tokens <= BASE.max_num_batched_tokens


def test_deterministic_outputs():
    _, r1, _ = serve_some(BASE, n=3, seed=42)
    _, r2, _ = serve_some(BASE, n=3, seed=42)
    for a, b in zip(r1, r2):
        assert np.array_equal(a.output_tokens(), b.output_tokens())


def test_logit_modes_equivalent_outputs():
    outs = {}
    for mode in ("monolithic", "chunked", "fused"):
        serve = dataclasses.replace(BASE, logit_mode=mode, vocab_tile=64)
        _, reqs, _ = serve_some(serve, n=3, seed=7)
        outs[mode] = [r.output_tokens().copy() for r in reqs]
    for a, b in zip(outs["monolithic"], outs["chunked"]):
        assert np.array_equal(a, b)
    for a, b in zip(outs["monolithic"], outs["fused"]):
        assert np.array_equal(a, b)


def test_request_scheduler_also_completes():
    serve = dataclasses.replace(BASE, scheduler="request",
                                selection="uniform",
                                logit_mode="monolithic")
    eng, reqs, stats = serve_some(serve, n=5)
    assert all(r.state == State.FINISHED for r in reqs)


def test_flash_kernel_engine_path():
    serve = dataclasses.replace(BASE, use_flash_kernel=True)
    eng, reqs, stats = serve_some(serve, n=3)
    assert all(r.state == State.FINISHED for r in reqs)


def test_kv_pool_isolation():
    """Requests in different slots must not corrupt each other: serving the
    same prompt alone or alongside others yields identical output."""
    cfg = reduced(ARCHS["llada-8b"])
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size - 1, 24)

    eng1 = Engine(cfg, BASE, seed=0)
    r_alone = eng1.submit(prompt, gen_len=16, arrival=0.0, rid=0)
    eng1.run()

    eng2 = Engine(cfg, BASE, seed=0)
    r_multi = eng2.submit(prompt, gen_len=16, arrival=0.0, rid=0)
    for i in range(3):
        eng2.submit(rng.integers(0, cfg.vocab_size - 1, 16),
                    gen_len=16, arrival=0.0, rid=10 + i)
    eng2.run()
    assert np.array_equal(r_alone.output_tokens(), r_multi.output_tokens())


def test_ssm_arch_serves():
    eng, reqs, stats = serve_some(BASE, n=3, arch="mamba2-130m")
    assert all(r.state == State.FINISHED for r in reqs)


def test_zero_refresh_cap_serves_padded_path():
    """max_refresh_per_iter=0 = unlimited (normalized refresh_slots): the
    padded engine must chunk by max_slots and serve to completion rather
    than livelock on an all-deferred plan."""
    serve = dataclasses.replace(BASE, max_refresh_per_iter=0)
    eng, reqs, stats = serve_some(serve, n=4)
    assert all(r.state == State.FINISHED for r in reqs)


def test_never_admittable_request_rejected_at_submit():
    """A request whose Refresh cost exceeds the token budget can never be
    admitted. It must come back from submit() in a terminal REJECTED state
    with a per-request error — and the engine must keep serving the rest of
    the traffic instead of raising the engine-wide stall RuntimeError (the
    pre-robustness behavior, which killed every resident request)."""
    from repro.core.request import Outcome
    serve = dataclasses.replace(BASE, max_num_batched_tokens=64)
    cfg = reduced(ARCHS["llada-8b"])
    eng = Engine(cfg, serve, seed=0)
    bad = eng.submit(np.zeros(60, np.int32), gen_len=16, arrival=0.0, rid=0)
    assert bad.state == State.REJECTED
    assert bad.outcome == Outcome.REJECTED_OVERSIZED
    assert "token budget" in bad.error
    ok = eng.submit(np.zeros(16, np.int32), gen_len=16, arrival=0.0, rid=1)
    stats = eng.run()                       # must NOT raise
    assert ok.state == State.FINISHED
    assert stats.submitted == 2 and stats.finished == 1
    assert stats.rejected_oversized == 1
    assert stats.conserved()


def test_oversized_for_max_seq_len_rejected_at_submit():
    """total_len > max_seq_len used to assert inside build_sequence; it must
    now surface as a structured rejection instead of a crash."""
    from repro.core.request import Outcome
    cfg = reduced(ARCHS["llada-8b"])
    eng = Engine(cfg, BASE, seed=0)
    bad = eng.submit(np.zeros(BASE.max_seq_len, np.int32), gen_len=16,
                     arrival=0.0, rid=0)
    assert bad.state == State.REJECTED
    assert bad.outcome == Outcome.REJECTED_OVERSIZED
    assert "max_seq_len" in bad.error
    eng.run()                               # empty queue, no raise


def test_run_raises_when_running_requests_all_deferred():
    """Regression for the silent ``break``: an iteration that makes no
    progress while unfinished RUNNING requests remain (and no future
    arrival can unblock them) must raise, not exit recording bogus stats.
    The post-fix scheduler cannot produce this state itself, so force it
    by deferring every running request at plan time. The message must name
    the stall and the stuck population (the operator's first triage cues)."""
    from repro.core.scheduler import IterationPlan
    cfg = reduced(ARCHS["llada-8b"])
    eng = Engine(cfg, BASE, seed=0)
    eng.submit(np.zeros(16, np.int32), gen_len=16, arrival=0.0, rid=0)
    real_plan = eng.scheduler.plan

    def defer_after_admission(now):
        if not eng.scheduler.running:
            return real_plan(now)
        return IterationPlan(deferred=list(eng.scheduler.running))

    eng.scheduler.plan = defer_after_admission
    with pytest.raises(RuntimeError, match="stalled") as ei:
        eng.run()
    msg = str(ei.value)
    assert "1 running" in msg and "0 waiting" in msg
    assert "invariant violation" in msg
    assert f"max_slots={BASE.max_slots}" in msg
    assert eng.scheduler.has_work          # nothing was silently dropped


def test_max_iters_exhaustion_returns_with_work_left():
    """max_iters is a hard iteration budget, not an error: run() must return
    the stats accumulated so far with unfinished requests still resident
    (resumable), never raise or mark them terminal."""
    cfg = reduced(ARCHS["llada-8b"])
    eng = Engine(cfg, BASE, seed=0)
    r = eng.submit(np.zeros(16, np.int32), gen_len=16, arrival=0.0, rid=0)
    stats = eng.run(max_iters=2)
    assert stats.iterations == 2
    assert r.state == State.RUNNING and r.outcome is None
    assert eng.scheduler.has_work
    assert not stats.conserved()           # by design: work is unfinished
    stats = eng.run(max_iters=100_000)     # resumable to completion
    assert r.state == State.FINISHED and stats.conserved()


def test_monotonic_rids_no_collision():
    """Engine-assigned rids are a monotonic counter (rng draws could collide
    and silently merge two requests' stats)."""
    cfg = reduced(ARCHS["llada-8b"])
    eng = Engine(cfg, BASE, seed=0)
    rids = [eng.submit(np.zeros(8, np.int32), gen_len=8).rid
            for _ in range(20)]
    assert rids == list(range(20))


def _jit_cache_keys(eng):
    return {
        "refresh": set(eng._refresh_jit),
        "refresh_packed": set(eng._refresh_packed_jit),
        "reuse": set(eng._reuse_jit),
        "reuse_packed": set(eng._reuse_packed_jit),
        "decode": set(eng._decode_jit),
        "decode_packed": set(eng._decode_packed_jit),
    }


def _key_bound(keys):
    """Componentwise max of a set of int or tuple jit-cache keys."""
    tup = [(k,) if isinstance(k, int) else tuple(k) for k in keys]
    if not tup:
        return None
    return tuple(max(t[i] for t in tup) for i in range(len(tup[0])))


@pytest.mark.parametrize("varlen,mrpi,sched", [
    (True, 0, "phase"), (True, 3, "phase"), (False, 0, "phase"),
    (False, 3, "phase"), (True, 2, "request")])
def test_warmup_covers_runtime_worst_case_buckets(varlen, mrpi, sched):
    """Warmup bucket audit: after warmup, no bucket the runtime requests may
    exceed the worst case already compiled — componentwise over every jit
    cache — so the expensive worst-case compile can never fire mid-serve.
    Exercises the normalized 0-means-unlimited refresh cap, a non-pow2 cap
    (pow2_bucket(3) = 4 > 3, the old loop bound), and the request-level
    scheduler whose whole-batch admission makes the fused packed dispatch
    span up to max_slots refreshes regardless of max_refresh_per_iter."""
    serve = dataclasses.replace(BASE, varlen_pack=varlen, token_bucket=64,
                                max_refresh_per_iter=mrpi, scheduler=sched)
    cfg = reduced(ARCHS["llada-8b"])
    eng = Engine(cfg, serve, seed=0)
    eng.warmup()
    warmed = {n: _key_bound(k) for n, k in _jit_cache_keys(eng).items()}
    rng = np.random.default_rng(1)
    for i in range(9):
        eng.submit(rng.integers(0, cfg.vocab_size - 1,
                                int(rng.integers(8, 40))),
                   gen_len=16, arrival=0.0, rid=i)
    eng.run()
    for name, keys in _jit_cache_keys(eng).items():
        bound = warmed[name]
        if bound is None:
            assert not keys, f"{name}: compiled without any warmup"
            continue
        after = _key_bound(keys)
        assert all(a <= w for a, w in zip(after, bound)), \
            (name, after, bound)


def test_warmup_padded_decode_stops_at_bucket_cover():
    """The padded decode warmup must stop exactly at the pow2 cover of the
    largest row count the runtime can request — the old ``while n <=
    max_logits * 2`` bound compiled one pow2 bucket beyond it whenever the
    cap was itself a power of two (here cap = (8+8)·8 = 128 rows: the old
    loop compiled a dead 256-row bucket)."""
    from repro.core.budgeting import pow2_bucket
    serve = dataclasses.replace(BASE, max_refresh_per_iter=0)  # cap pow2
    cfg = reduced(ARCHS["llada-8b"])
    eng = Engine(cfg, serve, seed=0)
    eng.warmup()
    Sb = serve.block_size
    cap = (serve.refresh_slots + serve.max_slots) * Sb
    assert max(eng._decode_jit) == pow2_bucket(cap, lo=Sb), \
        sorted(eng._decode_jit)


def test_iter_log_cap_bounds_growth():
    """iter_log_cap keeps only the newest rows (0 = unlimited): a long
    modeled-clock run must not grow host memory one dict per iteration."""
    serve = dataclasses.replace(BASE, iter_log_cap=4)
    eng, reqs, stats = serve_some(serve, n=5)
    assert stats.iterations > 4
    assert len(stats.iter_log) == 4
    # the retained rows are the NEWEST ones and aggregates stay lifetime
    assert stats.iter_log[-1]["t"] >= stats.iter_log[0]["t"]
    assert stats.committed_tokens == sum(r.gen_len for r in reqs)
    _, _, unlimited = serve_some(BASE, n=5)
    assert len(unlimited.iter_log) > 4


def test_warmup_survives_sub_block_token_budget():
    """max_num_batched_tokens < block_size is a degenerate config: warmup
    must still bound-compile without crashing (the engine then surfaces the
    serve-time stall explicitly, tested above)."""
    serve = dataclasses.replace(BASE, max_num_batched_tokens=4,
                                varlen_pack=True, token_bucket=64)
    cfg = reduced(ARCHS["llada-8b"])
    eng = Engine(cfg, serve, seed=0)
    eng.warmup()
    assert eng._refresh_packed_jit and eng._reuse_packed_jit
