"""Chaos suite: deterministic fault injection (core/faults.py).

End-state equivalence under every seeded schedule: same token ids as the
fault-free run for all non-shed requests, zero leaked slots, and the
EngineStats conservation law ``submitted == finished + shed + rejected``.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.base import ServeConfig
from repro.core.engine import Engine
from repro.core.faults import FaultError, FaultEvent, FaultPlan
from repro.core.request import State

BASE = ServeConfig(max_num_batched_tokens=512, max_num_logits=64,
                   block_size=8, steps_per_block=8, max_seq_len=128,
                   max_slots=4, max_refresh_per_iter=2,
                   selection="head", scheduler="phase", logit_mode="chunked",
                   preempt_starvation_s=0.05)


def _serve(faults=None, serve=BASE, n=5, arch="llada-8b", duplicate=False):
    cfg = reduced(ARCHS[arch])
    eng = Engine(cfg, serve, seed=0, clock="modeled", faults=faults)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size - 1, int(rng.integers(8, 40)))
               for _ in range(n)]
    if duplicate:
        # alias pairs onto identical prompts (stream drawn in full first)
        # so the shared-prefix ledger engages under the fault schedule
        prompts = [prompts[i // 2] for i in range(n)]
    reqs = [eng.submit(p, gen_len=16, arrival=0.05 * i, rid=i)
            for i, p in enumerate(prompts)]
    stats = eng.run()
    return eng, reqs, stats


# ---------------------------------------------------------------------------
# FaultPlan unit behaviour
# ---------------------------------------------------------------------------

def test_fault_plan_seeded_deterministic():
    a, b = FaultPlan.seeded(3), FaultPlan.seeded(3)
    assert a.events == b.events
    assert FaultPlan.seeded(4).events != a.events


def test_fault_event_rejects_unknown_kind():
    with pytest.raises(ValueError):
        FaultEvent("disk", at_iter=1)


def test_fault_plan_activation_and_consumption():
    plan = FaultPlan([FaultEvent("dispatch", at_iter=3, count=2),
                      FaultEvent("mem", at_iter=2, count=1, duration=2)])
    plan.begin_iteration(1)
    assert not plan.take_dispatch_fault("refresh")
    assert plan.stolen_slots() == 0 and plan.blocking()
    plan.begin_iteration(2)
    assert plan.stolen_slots() == 1
    plan.begin_iteration(3)
    assert plan.take_dispatch_fault("refresh")
    assert plan.take_dispatch_fault("decode")
    assert not plan.take_dispatch_fault("reuse")   # both tokens consumed
    plan.begin_iteration(4)
    assert plan.stolen_slots() == 0                # steal expired
    assert not plan.blocking()


def test_stage_scoped_dispatch_fault():
    plan = FaultPlan([FaultEvent("dispatch", at_iter=1, stage="decode")])
    plan.begin_iteration(1)
    assert not plan.take_dispatch_fault("refresh")
    assert plan.take_dispatch_fault("decode")


# ---------------------------------------------------------------------------
# chaos equivalence (the acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [1, 2, 3, 4, 5])
def test_chaos_end_state_equivalence(seed):
    """Every seeded schedule (dispatch faults below the retry limit, alloc
    faults, mem steals, slow iterations) must degrade — never corrupt:
    token ids identical to the fault-free run, no leaked slots, stats
    conservation."""
    _, ref_reqs, ref_stats = _serve()
    eng, reqs, stats = _serve(faults=FaultPlan.seeded(seed, horizon=60))
    assert stats.conserved()
    assert stats.finished == len(reqs)
    assert eng.pool.slots_in_use == []
    for a, b in zip(ref_reqs, reqs):
        assert b.state == State.FINISHED
        assert np.array_equal(a.output_tokens(), b.output_tokens()), \
            f"rid {b.rid} corrupted under fault seed {seed}"
    # commits discarded by preemption rollbacks are re-committed on replay,
    # so the lifetime counter exceeds the fault-free run by exactly the
    # recompute debt
    assert stats.committed_tokens == \
        ref_stats.committed_tokens + stats.recomputed_tokens


def test_chaos_packed_path():
    serve = dataclasses.replace(BASE, varlen_pack=True, token_bucket=64)
    _, ref_reqs, _ = _serve(serve=serve)
    eng, reqs, stats = _serve(faults=FaultPlan.seeded(9, horizon=60),
                              serve=serve)
    assert stats.conserved() and eng.pool.slots_in_use == []
    for a, b in zip(ref_reqs, reqs):
        assert np.array_equal(a.output_tokens(), b.output_tokens())


@pytest.mark.parametrize("seed", [1, 3, 7])
def test_chaos_shared_slots_never_leak(seed):
    """Chaos under the refcounted pool: mem steals, alloc faults, and
    preempt-and-requeue interleave with dedup hits and COW promotes, yet
    the end state is clean — token ids identical to the fault-free
    sharing-off run, zero leaked or double-freed shared slots (the ledger
    fully drains and its invariant suite holds), stats conservation."""
    serve = dataclasses.replace(BASE, prefix_sharing=True)
    _, ref_reqs, _ = _serve(serve=BASE, duplicate=True)
    eng, reqs, stats = _serve(faults=FaultPlan.seeded(seed, horizon=60),
                              serve=serve, duplicate=True)
    assert stats.conserved()
    assert stats.shared_hits > 0, "no dedup under faults — vacuous chaos"
    assert eng.pool.slots_in_use == [], "leaked logical slots"
    assert eng.pool.phys_slots_in_use == 0, "leaked shared content"
    assert eng.pool.ledger.owner_of == {}, "dangling references"
    eng.pool.ledger.check()
    for a, b in zip(ref_reqs, reqs):
        assert b.state == State.FINISHED
        assert np.array_equal(a.output_tokens(), b.output_tokens()), \
            f"rid {b.rid} corrupted under sharing + fault seed {seed}"


@pytest.mark.parametrize("seed", [2, 5])
def test_chaos_pipelined_matches_sync_loop(seed):
    """The dispatch-ahead loop under an identical seeded fault schedule is
    bit-identical to the synchronous oracle loop: the deferred sync changes
    WHEN token values land host-side, never what lands — including commits
    discarded by preemption epoch bumps and dispatches replayed by retries
    (docs/engine.md)."""
    sync = dataclasses.replace(BASE, pipeline=False)
    _, s_reqs, s_stats = _serve(faults=FaultPlan.seeded(seed, horizon=60),
                                serve=sync)
    _, p_reqs, p_stats = _serve(faults=FaultPlan.seeded(seed, horizon=60),
                                serve=BASE)
    for a, b in zip(s_reqs, p_reqs):
        assert a.state == b.state
        assert np.array_equal(a.tokens, b.tokens), f"rid {b.rid}"
    for k in ("iterations", "committed_tokens", "recomputed_tokens",
              "preemptions", "dispatch_retries", "alloc_fault_iters",
              "finished", "shed", "rejected"):
        assert getattr(s_stats, k) == getattr(p_stats, k), k
    assert abs(s_stats.wall_time - p_stats.wall_time) < 1e-9
    assert s_stats.dispatched_ahead == 0


# ---------------------------------------------------------------------------
# per-kind engine behaviour
# ---------------------------------------------------------------------------

def test_transient_dispatch_fault_retries_and_succeeds():
    plan = FaultPlan([FaultEvent("dispatch", at_iter=1, count=2)])
    eng, reqs, stats = _serve(faults=plan, n=2)
    assert stats.dispatch_retries == 2
    assert all(r.state == State.FINISHED for r in reqs)
    assert plan.injected["dispatch"] == 2


def test_permanent_dispatch_fault_raises_fault_error():
    """More consecutive failures than fault_retries = a real outage: the
    engine surfaces FaultError instead of retrying forever."""
    plan = FaultPlan([FaultEvent("dispatch", at_iter=1, count=10)])
    cfg = reduced(ARCHS["llada-8b"])
    eng = Engine(cfg, BASE, seed=0, clock="modeled", faults=plan)
    eng.submit(np.zeros(16, np.int32), gen_len=8, arrival=0.0, rid=0)
    with pytest.raises(FaultError, match="dispatch fault"):
        eng.run()


def test_retry_backoff_charges_modeled_clock():
    plan = FaultPlan([FaultEvent("dispatch", at_iter=1, count=2)])
    eng, _, stats = _serve(faults=plan, n=1)
    ref_eng, _, ref_stats = _serve(n=1)
    # two backoffs (launch_s and 2*launch_s) beyond the fault-free clock
    assert stats.wall_time > ref_stats.wall_time


def test_transient_alloc_fault_defers_admission():
    plan = FaultPlan([FaultEvent("alloc", at_iter=1, count=3)])
    eng, reqs, stats = _serve(faults=plan)
    assert stats.alloc_fault_iters >= 1
    assert all(r.state == State.FINISHED for r in reqs)
    assert stats.conserved()


def test_mem_pressure_steal_recovers():
    """Stealing every free slot for a window suppresses admission; the
    engine rides it out (and can preempt-to-reclaim if residents starve
    the queue) and still finishes everything."""
    plan = FaultPlan([FaultEvent("mem", at_iter=2, count=BASE.max_slots,
                                 duration=5)])
    eng, reqs, stats = _serve(faults=plan)
    assert all(r.state == State.FINISHED for r in reqs)
    assert stats.conserved() and eng.pool.slots_in_use == []


def test_slow_iteration_delay_charged():
    plan = FaultPlan([FaultEvent("slow", at_iter=1, delay_s=0.5)])
    eng, reqs, stats = _serve(faults=plan, n=2)
    assert stats.slow_fault_s == pytest.approx(0.5)
    assert all(r.state == State.FINISHED for r in reqs)
    ref = _serve(n=2)[2]
    # the delay overlaps idle waiting-for-arrival time, so the wall clock
    # grows by at least the non-overlapped part — and never shrinks
    assert stats.wall_time >= 0.5
    assert stats.wall_time > ref.wall_time
