"""Bit-identity of the pipelined dispatch-ahead loop vs the sync oracle.

The tentpole contract (docs/engine.md): ``pipeline=True`` restructures WHEN
host work happens — plan i+1 while i executes, ONE deferred device_get — but
must change NOTHING observable: token ids, every EngineStats counter, the
final KV-pool device cache, and the compile ledger are exact matches against
``pipeline=False`` (which syncs every iteration), on the modeled clock,
across padded/packed layouts, attention/SSM models, and under
preemption + injected faults.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.base import ServeConfig
from repro.core.engine import Engine
from repro.core.faults import FaultPlan
from repro.core.request import State

BASE = ServeConfig(max_num_batched_tokens=512, max_num_logits=64,
                   block_size=8, steps_per_block=8, max_seq_len=128,
                   max_slots=8, max_refresh_per_iter=2,
                   selection="head", scheduler="phase", logit_mode="chunked")

# every integer EngineStats counter — the conservation surface. Timing
# fields (host_plan_s & co) legitimately differ between the two loops;
# wall_time on the modeled clock is vtime and must match to fp tolerance.
COUNTERS = (
    "iterations", "refresh_steps", "reuse_steps", "committed_tokens",
    "deferred_steps", "peak_query_tokens",
    "refresh_tokens_real", "refresh_tokens_exec",
    "reuse_tokens_real", "reuse_tokens_exec",
    "logit_tokens_real", "logit_tokens_exec",
    "packed_refresh_calls", "padded_refresh_calls",
    "packed_reuse_calls", "padded_reuse_calls",
    "submitted", "finished", "rejected_oversized", "rejected_queue_full",
    "shed_deadline", "shed_queue", "preemptions", "recomputed_tokens",
    "dispatch_retries", "shared_hits", "shared_cow_promotes",
    "phys_slots_peak", "alloc_fault_iters",
)


def _run(pipeline, serve=BASE, arch="llada-8b", n=5, seed=0,
         fault_seed=None, stream_events=None, warm=False):
    cfg = reduced(ARCHS[arch])
    sv = dataclasses.replace(serve, pipeline=pipeline)
    faults = FaultPlan.seeded(fault_seed) if fault_seed is not None else None
    cb = stream_events.append if stream_events is not None else None
    eng = Engine(cfg, sv, seed=seed, clock="modeled", faults=faults,
                 stream_cb=cb)
    if warm:
        eng.warmup()
    rng = np.random.default_rng(seed)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size - 1,
                                    int(rng.integers(8, 40))),
                       gen_len=16, arrival=0.05 * i, rid=i)
            for i in range(n)]
    stats = eng.run()
    return eng, reqs, stats


def _assert_identical(sync, pipe):
    es, rs, ss = sync
    ep, rp, sp = pipe
    for a, b in zip(rs, rp):
        assert a.state == b.state
        assert np.array_equal(a.tokens, b.tokens), a.rid
    for k in COUNTERS:
        assert getattr(ss, k) == getattr(sp, k), k
    assert abs(ss.wall_time - sp.wall_time) < 1e-9
    # identical dispatch sequence => identical compile ledger: pipelining
    # may not introduce a single extra trace
    assert dict(ss.compile_counts) == dict(sp.compile_counts)
    # the final device caches saw the same write sequence
    cs, cp = jax.device_get((es.pool.cache, ep.pool.cache))
    for a, b in zip(jax.tree.leaves(cs), jax.tree.leaves(cp)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # and the loops really differed: dispatch-ahead overlapped host work
    assert ss.overlap_frac == 0.0 and ss.dispatched_ahead == 0
    if sp.iterations > 1:
        assert sp.overlap_frac > 0.0
        assert sp.dispatched_ahead > 0


@pytest.mark.parametrize("arch", ["llada-8b", "mamba2-130m"])
@pytest.mark.parametrize("packed", [False, True])
def test_pipelined_is_bit_identical(arch, packed):
    serve = dataclasses.replace(BASE, varlen_pack=packed)
    _assert_identical(_run(False, serve, arch=arch),
                      _run(True, serve, arch=arch))


def test_bit_identical_under_preemption_and_faults():
    """Chaos + starvation preemption: in-flight commits whose request was
    preempted must be discarded EXACTLY as the oracle overwrites them —
    epoch mismatches, rollback debt, and retries all line up."""
    serve = dataclasses.replace(BASE, max_slots=4,
                                preempt_starvation_s=0.05)
    sync = _run(False, serve, n=6, fault_seed=3)
    pipe = _run(True, serve, n=6, fault_seed=3)
    _assert_identical(sync, pipe)
    assert sync[2].preemptions + sync[2].dispatch_retries > 0, \
        "chaos run exercised neither preemption nor retries"


def test_zero_post_warmup_compiles_pipelined():
    """The dispatch-ahead loop reuses the same warmed entry points: a full
    pipelined serve after warmup adds ZERO compilations (padded path)."""
    eng, reqs, stats = _run(True, warm=True)
    assert all(r.state == State.FINISHED for r in reqs)
    assert stats.compiles_warmup > 0
    assert stats.compiles_post_warmup == 0, stats.compile_counts


def test_stream_callback_accounts_every_commit():
    events = []
    eng, reqs, stats = _run(True, stream_events=events)
    assert len(events) == stats.streamed_events > 0
    assert sum(e["n_committed"] for e in events) == stats.committed_tokens
    fin = [e for e in events if e["finished"]]
    assert len(fin) == len(reqs)
    # the final streamed block of each request matches its actual tokens
    for e in fin:
        r = reqs[e["rid"]]
        s = r.prompt_len + e["block_idx"] * BASE.block_size
        assert np.array_equal(e["tokens"], r.tokens[s:s + BASE.block_size])
    # events fire at the deferred sync, so timestamps are the modeled
    # commit times — monotone per request
    by_rid = {}
    for e in events:
        assert e["t"] >= by_rid.get(e["rid"], -1.0)
        by_rid[e["rid"]] = e["t"]


def test_iter_log_records_per_stage_host_times():
    _, _, stats = _run(True)
    rows = list(stats.iter_log)
    assert rows, "iter_log empty"
    for row in rows:
        assert row["plan_s"] >= 0.0 and row["fill_s"] >= 0.0
        assert row["sync_s"] >= 0.0
    # every dispatched iteration was synced exactly once: sync_wait_s is
    # the sum of the per-row sync times
    assert abs(sum(r["sync_s"] for r in rows) - stats.sync_wait_s) < 1e-6
