"""Synthetic workload traces: statistical/structural properties, plus the
seed-stability pins that protect the deterministic-deadline contract (the
prefix-sharing machinery must never perturb the established streams)."""
import hashlib

import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.data.workloads import (PrefixSpec, WORKLOADS, make_trace,
                                  prefix_share_factor, trace_prompts)


@pytest.mark.parametrize("name", WORKLOADS)
def test_trace_basics(name):
    tr = make_trace(name, 50, rps=2.0, seed=1)
    arr = [t.arrival for t in tr]
    assert arr == sorted(arr)
    assert all(t.prompt_len >= 4 and t.gen_len >= 4 for t in tr)
    prompts = trace_prompts(tr, vocab_size=1000, seed=1)
    assert all(len(p) == t.prompt_len for p, t in zip(prompts, tr))
    assert all(p.max() < 999 for p in prompts)


def test_burst_is_burstier_than_poisson():
    lb = make_trace("livebench", 200, rps=1.0, seed=2)
    bu = make_trace("burst", 200, rps=1.0, seed=2)
    cv = lambda t: np.std(np.diff([x.arrival for x in t])) / \
        np.mean(np.diff([x.arrival for x in t]))
    assert cv(bu) > cv(lb)


def test_osc_prompts_longer_than_livebench():
    lb = make_trace("livebench", 100, rps=1.0, seed=3)
    osc = make_trace("osc", 100, rps=1.0, seed=3)
    assert np.mean([t.prompt_len for t in osc]) > \
        np.mean([t.prompt_len for t in lb])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), rps=st.floats(0.2, 5.0))
def test_scaling_property(seed, rps):
    tr = make_trace("burst", 30, rps=rps, seed=seed, scale=0.1)
    assert all(t.prompt_len >= 4 for t in tr)
    full = make_trace("burst", 30, rps=rps, seed=seed, scale=1.0)
    assert sum(t.prompt_len for t in tr) < sum(t.prompt_len for t in full)


# ---------------------------------------------------------------------------
# seed-stability regression: the established streams are pinned
# ---------------------------------------------------------------------------

def _stream_digest(name, n=20, rps=2.0, seed=7, vocab=997):
    tr = make_trace(name, n, rps=rps, seed=seed)
    pr = trace_prompts(tr, vocab_size=vocab, seed=seed)
    h = hashlib.blake2b(digest_size=8)
    for t in tr:
        h.update(np.float64(t.arrival).tobytes())
        h.update(np.int64([t.prompt_len, t.gen_len]).tobytes())
    for p in pr:
        h.update(p.tobytes())
    return h.hexdigest()


@pytest.mark.parametrize("name,golden", [
    ("livebench", "a620ca93137f265f"),
    ("burst", "de96e4153cfb2ca9"),
    ("osc", "f647ab33a09e163c"),
])
def test_existing_streams_pinned(name, golden):
    """Byte-exact pins of the three pre-existing workloads (verified
    against the pre-prefix-pool implementation). The prefix machinery is
    only allowed to touch DERIVED rng streams — any drift here breaks PR
    6's deadline determinism and every trace-replay comparison."""
    assert _stream_digest(name) == golden


def test_trace_prompts_draws_once_per_request():
    """The prefix pool must not add main-stream draws: a prefix-annotated
    trace and a plain trace of identical geometry consume the SAME main
    stream (prefix content is overlaid from derived streams afterwards)."""
    tr = make_trace("shared-prefix", 12, rps=2.0, seed=5)
    plain = [type(t)(t.arrival, t.prompt_len, t.gen_len) for t in tr]
    with_pool = trace_prompts(tr, vocab_size=997, seed=5)
    without = trace_prompts(plain, vocab_size=997, seed=5)
    for a, b, t in zip(with_pool, without, tr):
        assert a.shape == b.shape
        # beyond the prefix overlay the draws are byte-identical
        assert np.array_equal(a[t.prefix_len:], b[t.prefix_len:])


# ---------------------------------------------------------------------------
# shared-prefix trace structure
# ---------------------------------------------------------------------------

def test_shared_prefix_pool_verbatim_and_grouped():
    spec = PrefixSpec(n_prefixes=3, prefix_len=16)
    tr = make_trace("shared-prefix", 24, rps=4.0, seed=2, prefix=spec)
    assert all(0 <= t.prefix_id < 3 for t in tr)
    assert len({t.prefix_id for t in tr}) > 1          # pool actually used
    prompts = trace_prompts(tr, vocab_size=997, seed=2)
    by_id = {}
    for t, p in zip(tr, prompts):
        assert t.prompt_len == t.prefix_len == 16      # tail_len=0 default
        by_id.setdefault(t.prefix_id, []).append(p)
    for ps in by_id.values():
        for p in ps[1:]:
            assert np.array_equal(p, ps[0]), "pool draw not verbatim"
    # same-id prompts identical => the share factor counts them as one
    groups = {(t.prefix_id, t.gen_len) for t in tr}
    assert prefix_share_factor(tr) == pytest.approx(24 / len(groups))


def test_shared_prefix_deterministic_and_deadline_pure():
    a = make_trace("shared-prefix", 10, rps=2.0, seed=9)
    b = make_trace("shared-prefix", 10, rps=2.0, seed=9,
                   deadline_slack=0.5)
    for x, y in zip(a, b):
        assert (x.arrival, x.prompt_len, x.gen_len, x.prefix_id) == \
            (y.arrival, y.prompt_len, y.gen_len, y.prefix_id)
        assert y.deadline == pytest.approx(y.arrival + 0.5)


def test_prefix_share_factor_unique_trace_is_one():
    assert prefix_share_factor(make_trace("livebench", 20, rps=2.0)) == 1.0