"""Synthetic workload traces: statistical/structural properties."""
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.data.workloads import WORKLOADS, make_trace, trace_prompts


@pytest.mark.parametrize("name", WORKLOADS)
def test_trace_basics(name):
    tr = make_trace(name, 50, rps=2.0, seed=1)
    arr = [t.arrival for t in tr]
    assert arr == sorted(arr)
    assert all(t.prompt_len >= 4 and t.gen_len >= 4 for t in tr)
    prompts = trace_prompts(tr, vocab_size=1000, seed=1)
    assert all(len(p) == t.prompt_len for p, t in zip(prompts, tr))
    assert all(p.max() < 999 for p in prompts)


def test_burst_is_burstier_than_poisson():
    lb = make_trace("livebench", 200, rps=1.0, seed=2)
    bu = make_trace("burst", 200, rps=1.0, seed=2)
    cv = lambda t: np.std(np.diff([x.arrival for x in t])) / \
        np.mean(np.diff([x.arrival for x in t]))
    assert cv(bu) > cv(lb)


def test_osc_prompts_longer_than_livebench():
    lb = make_trace("livebench", 100, rps=1.0, seed=3)
    osc = make_trace("osc", 100, rps=1.0, seed=3)
    assert np.mean([t.prompt_len for t in osc]) > \
        np.mean([t.prompt_len for t in lb])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), rps=st.floats(0.2, 5.0))
def test_scaling_property(seed, rps):
    tr = make_trace("burst", 30, rps=rps, seed=seed, scale=0.1)
    assert all(t.prompt_len >= 4 for t in tr)
    full = make_trace("burst", 30, rps=rps, seed=seed, scale=1.0)
    assert sum(t.prompt_len for t in tr) < sum(t.prompt_len for t in full)