"""Robustness layer: admission control, shedding, preempt-and-requeue, and
the KV slot-lifecycle ledger (docs/robustness.md)."""
import dataclasses

import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.base import ServeConfig
from repro.core.engine import Engine
from repro.core.kv_pool import KVPool
from repro.core.request import Outcome, Request, State

BASE = ServeConfig(max_num_batched_tokens=512, max_num_logits=64,
                   block_size=8, steps_per_block=8, max_seq_len=128,
                   max_slots=8, max_refresh_per_iter=2,
                   selection="head", scheduler="phase", logit_mode="chunked")


# ---------------------------------------------------------------------------
# KVPool slot-lifecycle ledger
# ---------------------------------------------------------------------------

def test_pool_take_free_generation():
    pool = KVPool(4)
    assert pool.slots_in_use == []
    g = pool.take(2)
    assert g == 0 and pool.slots_in_use == [2]
    pool.free([2])
    assert pool.generation(2) == 1 and pool.slots_in_use == []
    assert pool.take(2) == 1          # recycled slot carries the new gen


def test_pool_double_take_raises():
    pool = KVPool(4)
    pool.take(1)
    with pytest.raises(RuntimeError, match="in use"):
        pool.take(1)


def test_pool_double_free_raises():
    pool = KVPool(4)
    pool.take(1)
    pool.free([1])
    with pytest.raises(RuntimeError, match="double-free"):
        pool.free([1])


def test_pool_free_invalid_slot_raises():
    pool = KVPool(4)
    with pytest.raises(RuntimeError):
        pool.free([9])


def test_engine_detects_stale_slot_handle():
    """A slot freed (and gen-bumped) under a resident request must be caught
    at the next pool touch, not silently gather another request's KV."""
    cfg = reduced(ARCHS["llada-8b"])
    eng = Engine(cfg, BASE, seed=0)
    r = eng.submit(np.zeros(16, np.int32), gen_len=16, arrival=0.0, rid=0)
    assert eng.step(0.0)                  # admit + first Refresh
    eng.pool.free([r.slot])               # simulate a buggy/raced free
    with pytest.raises(RuntimeError, match="stale slot handle"):
        while eng.step(0.0):
            pass


def test_finish_returns_slot_no_leak():
    """scheduler.finish must return the slot to BOTH the free stack and the
    pool ledger exactly once; after a full drain nothing is in use."""
    cfg = reduced(ARCHS["llada-8b"])
    eng = Engine(cfg, BASE, seed=0)
    reqs = [eng.submit(np.zeros(12, np.int32), gen_len=16, rid=i)
            for i in range(5)]
    eng.run()
    assert all(r.state == State.FINISHED for r in reqs)
    assert eng.pool.slots_in_use == []
    assert sorted(eng.scheduler._free_slots) == list(range(BASE.max_slots))
    assert all(r.slot is None and r.slot_gen is None for r in reqs)


# ---------------------------------------------------------------------------
# bounded queue + deadlines
# ---------------------------------------------------------------------------

def test_queue_cap_reject_policy():
    serve = dataclasses.replace(BASE, queue_cap=2, queue_policy="reject")
    cfg = reduced(ARCHS["llada-8b"])
    eng = Engine(cfg, serve, seed=0)
    # arrivals in the future so the queue can't drain while we fill it
    reqs = [eng.submit(np.zeros(8, np.int32), gen_len=8, arrival=1.0, rid=i)
            for i in range(3)]
    assert reqs[2].state == State.REJECTED
    assert reqs[2].outcome == Outcome.REJECTED_QUEUE_FULL
    assert "queue_cap" in reqs[2].error
    stats = eng.run()
    assert reqs[0].state == reqs[1].state == State.FINISHED
    assert stats.rejected_queue_full == 1
    assert stats.conserved()


def test_queue_cap_evict_policy():
    serve = dataclasses.replace(BASE, queue_cap=2, queue_policy="evict")
    cfg = reduced(ARCHS["llada-8b"])
    eng = Engine(cfg, serve, seed=0)
    reqs = [eng.submit(np.zeros(8, np.int32), gen_len=8, arrival=1.0, rid=i)
            for i in range(3)]
    assert reqs[0].state == State.SHED     # oldest waiter evicted
    assert reqs[0].outcome == Outcome.SHED_QUEUE
    stats = eng.run()
    assert reqs[1].state == reqs[2].state == State.FINISHED
    assert stats.shed_queue == 1 and stats.conserved()


def test_deadline_expired_waiter_is_shed():
    """With one slot occupied by a long request, a deadlined waiter expires
    in the queue and is shed with a structured outcome — never an engine
    error, and the resident still finishes."""
    serve = dataclasses.replace(BASE, max_slots=1)
    cfg = reduced(ARCHS["llada-8b"])
    eng = Engine(cfg, serve, seed=0, clock="modeled")
    long_r = eng.submit(np.zeros(16, np.int32), gen_len=32, arrival=0.0,
                        rid=0)
    dead_r = eng.submit(np.zeros(16, np.int32), gen_len=8, arrival=0.0,
                        rid=1, deadline=1e-6)
    stats = eng.run()
    assert long_r.state == State.FINISHED
    assert dead_r.state == State.SHED
    assert dead_r.outcome == Outcome.SHED_DEADLINE
    assert stats.shed_deadline == 1 and stats.conserved()


def test_deadline_met_is_not_shed():
    cfg = reduced(ARCHS["llada-8b"])
    eng = Engine(cfg, BASE, seed=0, clock="modeled")
    r = eng.submit(np.zeros(16, np.int32), gen_len=8, arrival=0.0, rid=0,
                   deadline=1e9)
    stats = eng.run()
    assert r.state == State.FINISHED and r.met_deadline
    assert stats.shed == 0 and stats.conserved()


def test_overload_burst_never_raises():
    """Acceptance criterion: a Burst trace far beyond the admissible rate
    with queue_cap + deadlines completes with structured outcomes only."""
    from repro.launch.serve import run_serve
    res = run_serve("llada-8b", "dllm-serve", "burst", rps=40.0, n=24,
                    seed=0, queue_cap=4, queue_policy="evict",
                    deadline_slack=3.0, preempt_starvation_s=0.5,
                    max_slots=4, size_by_profiler=False)
    assert res["n_submitted"] == 24
    assert (res["n_finished"] + res["n_shed"] + res["n_rejected"]) == 24
    assert res["n_shed"] > 0              # saturating rate must shed
    assert res["goodput_tok_s"] <= res["throughput_tok_s"] + 1e-9


# ---------------------------------------------------------------------------
# preempt-and-requeue
# ---------------------------------------------------------------------------

def _serve_with_preemption(arch, varlen, preempt_s):
    """3 requests through 2 slots on the modeled clock; with a starvation
    threshold the waiter forces a preemption of the youngest Reuse resident."""
    serve = dataclasses.replace(
        BASE, max_slots=2, max_refresh_per_iter=2, varlen_pack=varlen,
        token_bucket=64, preempt_starvation_s=preempt_s)
    cfg = reduced(ARCHS[arch])
    eng = Engine(cfg, serve, seed=0, clock="modeled")
    rng = np.random.default_rng(3)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size - 1, 20),
                       gen_len=24, arrival=0.0, rid=i) for i in range(3)]
    stats = eng.run()
    return reqs, stats


@pytest.mark.parametrize("arch", ["llada-8b", "mamba2-130m"])
@pytest.mark.parametrize("varlen", [False, True])
def test_preemption_oracle_bit_identical(arch, varlen):
    """The tentpole property: a preempted-then-requeued request recomputes
    its cache via a normal Refresh and produces BIT-IDENTICAL output tokens
    to its unpreempted run — padded and packed paths, attention and SSM
    families (per-request denoising is batch-independent, and rollback
    restarts the active block's deterministic trajectory from step 0)."""
    base_reqs, base_stats = _serve_with_preemption(arch, varlen, 0.0)
    pre_reqs, pre_stats = _serve_with_preemption(arch, varlen, 0.02)
    assert base_stats.preemptions == 0
    assert pre_stats.preemptions > 0, "scenario failed to trigger preemption"
    assert pre_stats.recomputed_tokens >= 0
    for a, b in zip(base_reqs, pre_reqs):
        assert a.state == b.state == State.FINISHED
        assert np.array_equal(a.output_tokens(), b.output_tokens()), \
            f"rid {a.rid} diverged after preemption"
    assert pre_stats.conserved()
    preempted = [r for r in pre_reqs if r.n_preempted]
    assert preempted and all(r.recomputed_tokens >= 0 for r in preempted)


def test_preemption_capped_per_request():
    """max_preemptions bounds requeue thrash: no request is preempted more
    often than the cap, and everything still finishes."""
    serve = dataclasses.replace(BASE, max_slots=2, preempt_starvation_s=0.01,
                                max_preemptions=1)
    cfg = reduced(ARCHS["llada-8b"])
    eng = Engine(cfg, serve, seed=0, clock="modeled")
    reqs = [eng.submit(np.zeros(16, np.int32), gen_len=24, arrival=0.0,
                       rid=i) for i in range(4)]
    stats = eng.run()
    assert all(r.state == State.FINISHED for r in reqs)
    assert all(r.n_preempted <= 1 for r in reqs)
    assert stats.conserved()


def test_no_robustness_knobs_is_bit_identical_to_baseline():
    """Acceptance criterion: the default config (no faults, no deadlines,
    unbounded queue, no preemption) must produce the same outputs as before
    the robustness layer — here: identical across two fresh engines, with
    zero robustness events recorded."""
    def go():
        cfg = reduced(ARCHS["llada-8b"])
        eng = Engine(cfg, BASE, seed=7)
        rng = np.random.default_rng(7)
        reqs = [eng.submit(rng.integers(0, cfg.vocab_size - 1, 16),
                           gen_len=16, rid=i) for i in range(4)]
        stats = eng.run()
        return reqs, stats

    r1, s1 = go()
    r2, s2 = go()
    for a, b in zip(r1, r2):
        assert np.array_equal(a.output_tokens(), b.output_tokens())
    assert s1.preemptions == s1.shed == s1.rejected == 0
    assert s1.dispatch_retries == 0 and s1.conserved()
