"""Dry-run machinery tests.

The full 512-device production dry-run runs via ``python -m
repro.launch.dryrun`` (results in EXPERIMENTS.md). Here we verify the same
code path end-to-end in subprocesses with a small placeholder-device mesh —
smoke tests in this process must keep seeing exactly 1 device (checked).
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ,
           PYTHONPATH=os.path.join(REPO, "src"),
           REPRO_XLA_FLAGS="--xla_force_host_platform_device_count=8",
           REPRO_MESH="2,4")


def _run(args, timeout=420):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, env=ENV, cwd=REPO, timeout=timeout)


def test_main_process_sees_one_device():
    import jax
    assert jax.device_count() == 1


@pytest.mark.parametrize("arch,shape", [
    ("mamba2-130m", "decode_32k"),
    ("mamba2-130m", "train_4k"),
    ("gemma-2b", "decode_32k"),
])
def test_dryrun_cell_subprocess(arch, shape, tmp_path):
    out = tmp_path / "rec.json"
    r = _run(["--arch", arch, "--shape", shape, "--out", str(out)])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    recs = json.loads(out.read_text())
    assert recs[0]["ok"]
    assert recs[0]["flops_per_device"] > 0
    assert recs[0]["temp_bytes_per_device"] >= 0
    assert recs[0]["bottleneck"] in ("compute", "memory", "collective")


def test_dryrun_multipod_subprocess(tmp_path):
    env = dict(ENV, REPRO_MESH="2,2,2")
    out = tmp_path / "rec.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-130m",
         "--shape", "decode_32k", "--multipod", "--out", str(out)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=420)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    recs = json.loads(out.read_text())
    assert recs[0]["ok"] and recs[0]["mesh"] == "2x16x16"


def test_collective_walker_loop_correction():
    """A collective inside a scanned body must be multiplied by the trip
    count; this guards the §Roofline methodology."""
    hlo = """
HloModule test

%cond (p: (s32[])) -> pred[] {
  %p = (s32[]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(18)
  ROOT %lt = pred[] compare(%iv, %c), direction=LT
}

%body (p: (s32[])) -> (s32[]) {
  %p = (s32[]) parameter(0)
  %x = f32[1024] parameter(1)
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={}
  ROOT %t = (s32[]) tuple()
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8] parameter(0)
  %w = (s32[]) while(%t0), condition=%cond, body=%body
  %ag = f32[256]{0} all-gather(%a), dimensions={0}
  ROOT %r = f32[8] copy(%a)
}
"""
    from repro.roofline.analysis import collective_bytes
    cb = collective_bytes(hlo)
    assert cb["all-reduce"] == 1024 * 4 * 18     # trip-count multiplied
    assert cb["all-gather"] == 256 * 4


def test_analytic_flops_match_hand_calculation():
    from repro.configs import get_config
    from repro.configs.base import SHAPES_BY_NAME
    from repro.roofline.flops import analytic_cost
    cfg = get_config("qwen2-72b")
    shape = SHAPES_BY_NAME["train_4k"]
    a = analytic_cost(cfg, shape, dp=16, tp=16, microbatches=8, remat=True)
    # 6ND lower bound: total compiled flops must exceed the model flops
    # (remat + attention + loss overhead), but by less than 4x
    model = 6 * cfg.n_params() * shape.tokens
    total = a["flops_global"]
    assert model < total < 4 * model, (model, total)
