"""Per-kernel allclose validation against the pure-jnp oracles (interpret
mode), with shape/dtype sweeps + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def keys(n):
    return jax.random.split(KEY, n)


# ---------------------------------------------------------------------------
# fused logit argmax (C1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,D,V", [(32, 64, 512), (100, 128, 1024),
                                   (256, 96, 2048), (8, 256, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_logit_argmax_matches_ref(T, D, V, dtype, softcap):
    k1, k2 = keys(2)
    h = jax.random.normal(k1, (T, D), dtype)
    w = (jax.random.normal(k2, (D, V), jnp.float32) * 0.05).astype(dtype)
    ids, conf = ops.fused_logit_argmax(h, w, softcap=softcap,
                                       vocab_tile=256, t_tile=32)
    ids_r, conf_r = ref.fused_logit_argmax(h, w, softcap=softcap)
    assert np.array_equal(np.asarray(ids), np.asarray(ids_r))
    np.testing.assert_allclose(np.asarray(conf), np.asarray(conf_r),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=20, deadline=None)
@given(t=st.integers(1, 64), logv=st.integers(3, 7), seed=st.integers(0, 99))
def test_logit_argmax_property(t, logv, seed):
    """Argmax invariance: any (T, V) grid, any tile split, same winner."""
    V = 2 ** logv * 8
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    h = jax.random.normal(k1, (t, 32))
    w = jax.random.normal(k2, (32, V)) * 0.1
    ids, conf = ops.fused_logit_argmax(h, w, vocab_tile=8, t_tile=8)
    ids_r, _ = ref.fused_logit_argmax(h, w)
    assert np.array_equal(np.asarray(ids), np.asarray(ids_r))
    assert np.all(np.asarray(conf) > 0) and np.all(np.asarray(conf) <= 1.0 + 1e-5)


def test_logit_argmax_vs_monolithic_decode():
    """The budgeted decode path (C1) must equal the monolithic baseline."""
    from repro.configs import ARCHS, reduced
    from repro.models import backbone as BB
    from repro.models import lm_head as LM
    cfg = reduced(ARCHS["llada-8b"])
    params = BB.init_params(cfg, KEY)
    h = jax.random.normal(keys(1)[0], (96, cfg.d_model))
    outs = {}
    for mode in ("monolithic", "chunked", "fused"):
        ids, conf = LM.decode_tokens(params["embed"], cfg, h,
                                     max_num_logits=32, mode=mode,
                                     vocab_tile=64)
        outs[mode] = (np.asarray(ids), np.asarray(conf))
    assert np.array_equal(outs["monolithic"][0], outs["chunked"][0])
    assert np.array_equal(outs["monolithic"][0], outs["fused"][0])
    np.testing.assert_allclose(outs["monolithic"][1], outs["fused"][1],
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# packed flash attention (C3 reuse path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,K,G,Sb,T,dh", [
    (2, 2, 2, 8, 64, 16), (1, 4, 1, 4, 128, 32), (3, 1, 8, 16, 96, 64),
])
@pytest.mark.parametrize("softcap", [0.0, 50.0])
def test_flash_attention_matches_ref(B, K, G, Sb, T, dh, softcap):
    H = K * G
    k1, k2, k3, k4 = keys(4)
    q = jax.random.normal(k1, (B, Sb, H, dh))
    k = jax.random.normal(k2, (B, K, T, dh))
    v = jax.random.normal(k3, (B, K, T, dh))
    mask = jax.random.bernoulli(k4, 0.75, (B, K, Sb, T)).at[..., 0].set(True)
    out = ops.packed_flash_attention(q, k, v, mask, softcap=softcap, t_tile=32)
    qr = q.reshape(B, Sb, K, G, dh).transpose(0, 2, 1, 3, 4).reshape(B, K, Sb * G, dh)
    out_r = ref.packed_flash_attention(qr, k, v, mask, softcap=softcap)
    out_r = out_r.reshape(B, K, Sb, G, dh).transpose(0, 2, 1, 3, 4).reshape(B, Sb, H, dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r), atol=3e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 999), t_tile=st.sampled_from([16, 32, 64]))
def test_flash_attention_tile_invariance(seed, t_tile):
    """Online-softmax accumulation must be invariant to KV tile size."""
    r = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(r, 3)
    q = jax.random.normal(k1, (1, 4, 4, 16))
    k = jax.random.normal(k2, (1, 2, 64, 16))
    v = jax.random.normal(k3, (1, 2, 64, 16))
    mask = jnp.ones((1, 2, 4, 64), bool)
    a = ops.packed_flash_attention(q, k, v, mask, t_tile=t_tile)
    b = ops.packed_flash_attention(q, k, v, mask, t_tile=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# head-score kernel (C3 refresh path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,K,G,Sb,S,dh", [(2, 3, 2, 8, 96, 16),
                                           (1, 8, 1, 32, 256, 32)])
def test_head_score_matches_ref(B, K, G, Sb, S, dh):
    H = K * G
    k1, k2 = keys(2)
    q = jax.random.normal(k1, (B, Sb, H, dh))
    kf = jax.random.normal(k2, (B, S, K, dh))
    sc = ops.head_score(q, kf, s_tile=32)
    qr = q.reshape(B, Sb, K, G, dh).transpose(0, 2, 1, 3, 4).reshape(B, K, Sb * G, dh)
    sc_r = ref.head_score(qr, kf.transpose(0, 2, 1, 3))
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sc_r), atol=1e-5)


def test_head_score_kernel_matches_model_scoring():
    """Kernel scores == the model-side jnp scoring used by select_and_pack."""
    from repro.models.sparse_select import head_scores
    B, Sb, K, G, S, dh = 2, 8, 4, 2, 64, 16
    H = K * G
    k1, k2 = keys(2)
    q = jax.random.normal(k1, (B, Sb, H, dh))
    kf = jax.random.normal(k2, (B, S, K, dh))
    raw_kernel = ops.head_score(q, kf)
    raw_model = head_scores(q, kf, kernel_size=1)
    np.testing.assert_allclose(np.asarray(raw_kernel), np.asarray(raw_model),
                               atol=1e-5)
