import os
import sys

# src/ onto the path so `pytest tests/` works without an install.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# must see 1 device. Dry-run tests spawn subprocesses with their own flags.
