"""Modality-frontend (vlm/audio) token packing: the frontend-prefix path.

Each request's segment in the packed Refresh stream is ``[frontend prefix ;
text]`` — ``frontend_len`` projected rows scattered ahead of the text
embeddings (``backbone.embed_inputs_packed``). The padded
``serve_refresh``/``serve_reuse``/``decode_tokens`` paths stay the
correctness oracles (same policy as every other family): block hidden AND
captured caches must agree, the engine must serve vlm/audio with zero
pow2-padded dispatches under ``varlen_pack``, and frontend prefixes must
never leak into the Reuse or logit cu_seqlens.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.configs import ARCHS, reduced
from repro.configs.base import ServeConfig
from repro.core.engine import Engine
from repro.core.request import Request, State
from repro.core.scheduler import PhaseMultiplexedScheduler
from repro.kernels.flash_varlen import PAD_SEG
from repro.models import backbone as BB
from repro.models import transformer as T

KEY = jax.random.PRNGKey(19)

FRONTEND_ARCHS = ("internvl2-76b", "musicgen-medium")

SERVE = ServeConfig(max_num_batched_tokens=512, max_num_logits=64,
                    block_size=8, steps_per_block=8, max_seq_len=128,
                    max_slots=8, max_refresh_per_iter=2,
                    selection="head", scheduler="phase", logit_mode="chunked",
                    varlen_pack=True, token_bucket=64)


def _frontend_batch(cfg, lens, S, seed=0):
    """Padded-batch AND packed-stream views of one ragged frontend batch.

    Returns (toks [B,S], valid [B,F+S], fe [B,F,fdim], flat stream pieces):
    every stream segment is F prefix rows followed by the request's text."""
    rng = np.random.default_rng(seed)
    F = cfg.frontend_len
    B = len(lens)
    toks = np.zeros((B, S), np.int32)
    valid = np.zeros((B, F + S), bool)
    fe = rng.standard_normal((B, F, cfg.frontend_dim)).astype(np.float32)
    for j, L in enumerate(lens):
        toks[j, :L] = rng.integers(0, cfg.vocab_size - 1, L)
        valid[j, : F + L] = True
    t_real = sum(F + L for L in lens)
    tp = -(-t_real // 64) * 64
    flat = np.zeros(tp, np.int32)
    pos = np.zeros(tp, np.int32)
    seg = np.full(tp, PAD_SEG, np.int32)
    val = np.zeros(tp, bool)
    cu = np.full(B, max(0, tp - 1), np.int32)
    sl = np.zeros(B, np.int32)
    off = 0
    for j, L in enumerate(lens):
        ln = F + L
        flat[off + F: off + ln] = toks[j, :L]
        pos[off: off + ln] = np.arange(ln)
        seg[off: off + ln] = j
        val[off: off + ln] = True
        cu[j] = off
        sl[j] = ln
        off += ln
    return toks, valid, fe, flat, pos, seg, val, cu, sl


@pytest.mark.parametrize("arch", FRONTEND_ARCHS)
@pytest.mark.parametrize("use_kernel", [False, True])
def test_packed_refresh_matches_padded_frontend(arch, use_kernel):
    """serve_refresh_packed with frontend-prefix segments: block hidden AND
    the captured packed-KV cache must reproduce the padded oracle."""
    cfg = reduced(ARCHS[arch])
    F = cfg.frontend_len
    params = BB.init_params(cfg, KEY)
    ctx = T.ServeContext(block_size=8, retain=24, q_chunk=32, max_seq_len=96)
    ctx_pk = dataclasses.replace(ctx, use_flash_refresh=use_kernel)
    rng = np.random.default_rng(29)
    for trial in range(2):
        lens = [int(x) for x in rng.integers(12, 96, size=3)]
        # block offsets in FULL-sequence coordinates (prefix first)
        bstarts = F + np.array([((L - 8) // 8) * 8 for L in lens], np.int32)
        toks, valid, fe, flat, pos, seg, val, cu, sl = _frontend_batch(
            cfg, lens, 96, seed=trial)
        out_pad = BB.serve_refresh(
            params, cfg, jnp.asarray(toks), jnp.asarray(bstarts), ctx,
            frontend=jnp.asarray(fe), token_valid=jnp.asarray(valid))
        out_pk = BB.serve_refresh_packed(
            params, cfg, jnp.asarray(flat), jnp.asarray(pos),
            jnp.asarray(seg), jnp.asarray(val), jnp.asarray(cu),
            jnp.asarray(sl), jnp.asarray(bstarts), ctx_pk,
            frontend=jnp.asarray(fe))
        np.testing.assert_allclose(
            np.asarray(out_pk.block_hidden, np.float32),
            np.asarray(out_pad.block_hidden, np.float32), atol=1e-4)
        # the retained sets must agree too — frontend rows are selectable
        # exactly like text rows on both paths
        pos_eq = (np.asarray(out_pk.cache.pos)
                  == np.asarray(out_pad.cache.pos)).mean()
        assert pos_eq > 0.99, pos_eq


@pytest.mark.parametrize("arch", FRONTEND_ARCHS)
@pytest.mark.parametrize("use_kernel", [False, True])
def test_packed_reuse_matches_padded_frontend(arch, use_kernel):
    """serve_reuse_packed for frontend archs (text-only block stream against
    caches that may retain frontend rows) must reproduce the padded oracle."""
    cfg = reduced(ARCHS[arch])
    F = cfg.frontend_len
    params = BB.init_params(cfg, KEY)
    ctx = T.ServeContext(block_size=8, retain=24, q_chunk=32, max_seq_len=96)
    ctx_pk = dataclasses.replace(ctx, use_flash_kernel=use_kernel)
    rng = np.random.default_rng(31)
    lens = [int(x) for x in rng.integers(16, 96, size=3)]
    bs_text = np.array([((L - 8) // 8) * 8 for L in lens], np.int32)
    bstarts = F + bs_text
    toks, valid, fe, *_ = _frontend_batch(cfg, lens, 96, seed=1)
    out = BB.serve_refresh(
        params, cfg, jnp.asarray(toks), jnp.asarray(bstarts), ctx,
        frontend=jnp.asarray(fe), token_valid=jnp.asarray(valid))
    btok = np.stack([toks[j, bs_text[j]: bs_text[j] + 8]
                     for j in range(len(lens))])
    bpos = np.stack([np.arange(b, b + 8) for b in bstarts]).astype(np.int32)
    h_pad = BB.serve_reuse(params, cfg, jnp.asarray(btok), jnp.asarray(bpos),
                           out.cache, ctx)
    h_pk = BB.serve_reuse_packed(
        params, cfg, jnp.asarray(btok.reshape(-1)),
        jnp.asarray(bpos.reshape(-1)), out.cache, ctx_pk)
    np.testing.assert_allclose(
        np.asarray(h_pk, np.float32).reshape(len(lens), 8, -1),
        np.asarray(h_pad, np.float32), atol=2e-4)


def test_embed_inputs_packed_never_clobbers_real_tail():
    """A bucket-exact stream (t_real == tp) puts the pad requests' redirect
    row AT a real token: embed_inputs_packed must scatter frontend rows for
    real requests only (pad requests carry seq_len 0 and are dropped)."""
    cfg = reduced(ARCHS["internvl2-76b"])
    F = cfg.frontend_len
    params = BB.init_params(cfg, KEY)
    rng = np.random.default_rng(2)
    tp = 32
    flat = rng.integers(0, cfg.vocab_size - 1, tp).astype(np.int32)
    # one real request filling the bucket exactly + one pad request whose
    # cu points at the (real) final row, the engine's pad convention
    cu = jnp.asarray(np.array([0, tp - 1], np.int32))
    sl = jnp.asarray(np.array([tp, 0], np.int32))
    fe = jnp.asarray(
        rng.standard_normal((2, F, cfg.frontend_dim)).astype(np.float32))
    x = BB.embed_inputs_packed(params, cfg, jnp.asarray(flat), cu, sl, fe)
    from repro.models import lm_head as LM
    ref = LM.embed_tokens(params["embed"], jnp.asarray(flat))
    proj = jnp.einsum("rfe,ed->rfd", fe, params["frontend"]["proj"])
    # prefix rows of the real request carry the projected frontend ...
    np.testing.assert_allclose(np.asarray(x[:F]), np.asarray(proj[0]),
                               atol=1e-6)
    # ... and every other row, INCLUDING the final one the pad request
    # points at, is the untouched token embedding
    np.testing.assert_allclose(np.asarray(x[F:]), np.asarray(ref[F:]),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# layout: frontend prefixes live in Refresh cu_seqlens ONLY
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 8), budget=st.integers(128, 512),
       cap=st.integers(1, 4), seed=st.integers(0, 99))
def test_frontend_prefix_never_leaks_into_reuse_or_logit(n, budget, cap,
                                                         seed):
    """Property: with frontend-carrying requests, every Refresh segment is
    frontend_len + total_len rows while Reuse segments stay exactly
    block_size and logit_tokens counts one TEXT block per scheduled request
    — the prefix can never leak into the Reuse or logit streams."""
    F, fdim = 4, 8
    cfg = dataclasses.replace(SERVE, max_num_batched_tokens=budget)
    sched = PhaseMultiplexedScheduler(cfg)
    rng = np.random.default_rng(seed)
    for i in range(n):
        plen = int(rng.integers(4, 48))
        if plen + 16 + 8 > cfg.max_seq_len or F + plen + 16 > budget:
            plen = 8
        fe = rng.standard_normal((F, fdim)).astype(np.float32)
        sched.submit(Request(rid=i, prompt=np.zeros(plen, np.int32),
                             gen_len=16, arrival=0.0, cfg=cfg, mask_id=255,
                             frontend=fe))
    for _ in range(4):
        plan = sched.plan(now=1e9)
        layout = plan.packed_layout(cap)
        for seg in layout.refresh_chunks:
            assert seg.token_counts == \
                [F + r.total_len for r in seg.requests]
        if layout.refresh_fused:
            assert layout.refresh_fused.token_counts == \
                [F + r.total_len for r in plan.refresh]
        if layout.reuse:
            cu = layout.reuse.cu_seqlens
            assert list(np.diff(cu)) == [cfg.block_size] * len(plan.reuse)
        assert layout.logit_tokens == \
            (len(plan.refresh) + len(plan.reuse)) * cfg.block_size
        # scheduling currency counts the prefix in Refresh only
        assert plan.query_tokens <= budget
        for r in plan.refresh:
            assert r.query_tokens == F + r.total_len
        for r in plan.reuse:
            assert r.query_tokens == cfg.block_size
        for r in plan.refresh + plan.reuse:
            blk = r.block_tokens().copy()
            blk[:] = 1
            r.advance(blk, now=0.0)
            if r.state == State.FINISHED:
                sched.finish(r)


# ---------------------------------------------------------------------------
# engine: vlm/audio serve fully packed, padded oracle agrees end-to-end
# ---------------------------------------------------------------------------

def _serve_engine(serve, arch, n=5, seed=3, forbid_padded=False):
    cfg = reduced(ARCHS[arch])
    eng = Engine(cfg, serve, seed=seed)
    if forbid_padded:
        def _boom(*a, **k):
            raise AssertionError("pow2-padded dispatch on the packed path")
        eng._run_refresh = _boom
        eng._run_reuse = _boom
        eng._decode_fn = _boom
    rng = np.random.default_rng(seed)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size - 1,
                                    int(rng.integers(8, 40))),
                       gen_len=16, arrival=0.0, rid=i) for i in range(n)]
    stats = eng.run()
    return eng, reqs, stats


@pytest.mark.parametrize("arch", FRONTEND_ARCHS)
def test_engine_frontend_archs_run_packed(arch):
    """Acceptance: under varlen_pack a vlm and an audio config serve
    Refresh, Reuse, AND the logit stage with zero pow2-padded dispatches."""
    eng, reqs, stats = _serve_engine(SERVE, arch, n=4, forbid_padded=True)
    assert all(r.state == State.FINISHED for r in reqs)
    assert all((r.output_tokens() != eng.mask_id).all() for r in reqs)
    assert stats.packed_refresh_calls > 0 and stats.padded_refresh_calls == 0
    assert stats.packed_reuse_calls > 0 and stats.padded_reuse_calls == 0


@pytest.mark.parametrize("arch", FRONTEND_ARCHS)
def test_engine_frontend_packed_padded_same_totals(arch):
    """The packed frontend-prefix engine and the padded oracle commit the
    same tokens on the same workload (identical per-request outputs), and
    packed waste is never worse on any stage."""
    _, r_pk, s_pk = _serve_engine(SERVE, arch, n=5, seed=3)
    _, r_pd, s_pd = _serve_engine(
        dataclasses.replace(SERVE, varlen_pack=False), arch, n=5, seed=3)
    assert s_pk.committed_tokens == s_pd.committed_tokens
    assert all(r.state == State.FINISHED for r in r_pk + r_pd)
    for a, b in zip(r_pk, r_pd):
        assert np.array_equal(a.output_tokens(), b.output_tokens())
    # real counts include the frontend prefix on both paths; the padded
    # oracle pays the pow2 [batch, frontend_len + max_seq_len] rectangle
    assert s_pk.refresh_tokens_real == s_pd.refresh_tokens_real
    assert s_pk.refresh_tokens_exec < s_pd.refresh_tokens_exec
    assert s_pk.refresh_waste <= s_pd.refresh_waste
    assert s_pk.reuse_waste <= s_pd.reuse_waste
    assert s_pk.logit_waste <= s_pd.logit_waste


def test_engine_frontend_warmup_covers_runtime_buckets():
    """The warmup bucket audit extends to frontend archs: runtime may never
    request a (token, request) bucket beyond what warmup compiled."""
    def keys(eng):
        return {"refresh": set(eng._refresh_jit),
                "refresh_packed": set(eng._refresh_packed_jit),
                "reuse": set(eng._reuse_jit),
                "reuse_packed": set(eng._reuse_packed_jit),
                "decode": set(eng._decode_jit),
                "decode_packed": set(eng._decode_packed_jit)}

    def bound(ks):
        t = [(k,) if isinstance(k, int) else tuple(k) for k in ks]
        return None if not t else tuple(max(x[i] for x in t)
                                        for i in range(len(t[0])))

    cfg = reduced(ARCHS["internvl2-76b"])
    eng = Engine(cfg, SERVE, seed=0)
    eng.warmup()
    warmed = {n: bound(k) for n, k in keys(eng).items()}
    rng = np.random.default_rng(1)
    for i in range(7):
        eng.submit(rng.integers(0, cfg.vocab_size - 1,
                                int(rng.integers(8, 40))),
                   gen_len=16, arrival=0.0, rid=i)
    eng.run()
    for name, ks in keys(eng).items():
        b = warmed[name]
        if b is None:
            assert not ks, f"{name}: compiled without any warmup"
            continue
        a = bound(ks)
        assert all(x <= w for x, w in zip(a, b)), (name, a, b)
