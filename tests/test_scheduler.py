"""Phase-Multiplexed Scheduler invariants (hypothesis property tests)."""
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.configs.base import ServeConfig
from repro.core.request import Outcome, Phase, Request, State
from repro.core.scheduler import (PhaseMultiplexedScheduler,
                                  RequestLevelScheduler)


def mk_cfg(**kw):
    base = dict(max_num_batched_tokens=256, block_size=8, steps_per_block=8,
                max_seq_len=128, max_slots=8, max_refresh_per_iter=2,
                refresh_interval=4)
    base.update(kw)
    return ServeConfig(**base)


def mk_req(rid, cfg, plen=16, glen=16, arrival=0.0):
    return Request(rid=rid, prompt=np.zeros(plen, np.int32), gen_len=glen,
                   arrival=arrival, cfg=cfg, mask_id=255)


def drain(sched, cfg, max_iters=500):
    """Run the scheduler state machine with a fake executor.

    Snapshots query_tokens at plan time (the property reads live request
    state, which mutates as the fake executor advances)."""
    plans = []
    it = 0
    while sched.has_work and it < max_iters:
        plan = sched.plan(now=1e9)
        plan.query_tokens_snapshot = plan.query_tokens
        plans.append(plan)
        for r in plan.refresh + plan.reuse:
            blk = r.block_tokens().copy()
            masked = np.where(blk == r.mask_id)[0]
            if masked.size:
                blk[masked[0]] = 1    # commit one token per step
            r.advance(blk, now=it)
            if r.state == State.FINISHED:
                sched.finish(r)
        it += 1
        if not plan.refresh and not plan.reuse:
            break
    return plans


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 12), plen=st.integers(4, 60),
       glen=st.integers(8, 40), budget=st.integers(64, 512),
       seed=st.integers(0, 99))
def test_token_budget_invariant(n, plen, glen, budget, seed):
    """Σ query tokens in any packed iteration ≤ max_num_batched_tokens,
    provided the budget admits at least one request."""
    cfg = mk_cfg(max_num_batched_tokens=budget)
    if plen + glen + 8 > cfg.max_seq_len:
        plen = cfg.max_seq_len - glen - 8
    sched = PhaseMultiplexedScheduler(cfg)
    rng = np.random.default_rng(seed)
    reqs = [mk_req(i, cfg, plen=max(1, int(rng.integers(1, plen + 1))),
                   glen=glen) for i in range(n)]
    if any(r.total_len > budget for r in reqs):
        return  # request can never fit; admission correctly starves
    for r in reqs:
        sched.submit(r)
    plans = drain(sched, cfg)
    for p in plans:
        assert p.query_tokens_snapshot <= budget
    assert all(r.state == State.FINISHED for r in reqs)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 10), seed=st.integers(0, 99))
def test_fcfs_admission_order(n, seed):
    cfg = mk_cfg()
    sched = PhaseMultiplexedScheduler(cfg)
    reqs = [mk_req(i, cfg, plen=8, glen=8, arrival=0.0) for i in range(n)]
    for r in reqs:
        sched.submit(r)
    drain(sched, cfg)
    admits = [r.t_admitted for r in reqs]
    assert all(a >= 0 for a in admits)
    assert admits == sorted(admits)    # FCFS: earlier submit admitted no later


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 20), seed=st.integers(0, 99))
def test_slots_never_oversubscribed(n, seed):
    cfg = mk_cfg(max_slots=4)
    sched = PhaseMultiplexedScheduler(cfg)
    for i in range(n):
        sched.submit(mk_req(i, cfg, plen=8, glen=8))
    it = 0
    while sched.has_work and it < 500:
        plan = sched.plan(now=1e9)
        slots = [r.slot for r in sched.running]
        assert len(slots) <= 4
        assert len(set(slots)) == len(slots)   # unique
        for r in plan.refresh + plan.reuse:
            blk = r.block_tokens().copy()
            blk[:] = 1
            r.advance(blk, now=it)
            if r.state == State.FINISHED:
                sched.finish(r)
        it += 1


def test_zero_refresh_cap_means_unlimited():
    """Regression: ``max_refresh_per_iter=0`` is documented as "no per-iter
    cap" (0 = one packed chunk), but the scheduler compared the raw field —
    ``len(plan.refresh) < 0`` — so every Refresh was deferred forever and
    admission was blocked with it (livelock). The normalized
    ``ServeConfig.refresh_slots`` must admit and refresh normally."""
    cfg = mk_cfg(max_refresh_per_iter=0)
    assert cfg.refresh_slots == cfg.max_slots
    sched = PhaseMultiplexedScheduler(cfg)
    reqs = [mk_req(i, cfg, plen=8, glen=8) for i in range(4)]
    for r in reqs:
        sched.submit(r)
    plan = sched.plan(now=1e9)
    assert plan.refresh and plan.admitted, \
        "max_refresh_per_iter=0 deferred every Refresh (livelock)"
    assert not plan.deferred
    drain(sched, cfg)
    assert all(r.state == State.FINISHED for r in reqs)


def test_refresh_cap_still_binds_when_positive():
    cfg = mk_cfg(max_refresh_per_iter=2)
    sched = PhaseMultiplexedScheduler(cfg)
    for i in range(6):
        sched.submit(mk_req(i, cfg, plen=8, glen=8))
    plan = sched.plan(now=1e9)
    assert len(plan.refresh) == 2


def test_phase_machine_cadence():
    cfg = mk_cfg(refresh_interval=4, steps_per_block=8)
    r = mk_req(0, cfg, plen=8, glen=16)
    phases = []
    for step in range(16):
        phases.append(r.phase)
        blk = r.block_tokens().copy()
        masked = np.where(blk == r.mask_id)[0]
        blk[masked[:2]] = 1
        r.advance(blk, now=step)
    # step 0 of each block refreshes; step 4 (interval) refreshes
    assert phases[0] == Phase.REFRESH
    assert phases[1] == Phase.REUSE
    assert phases[4] == Phase.REFRESH


def test_phase_scheduler_admits_more_than_request_level():
    """The paper's core scheduling claim: multiplexing Refresh/Reuse admits
    more concurrent work under the same token budget."""
    def peak_concurrency(klass):
        cfg = mk_cfg(max_num_batched_tokens=128, max_slots=8,
                     refresh_interval=0)
        sched = klass(cfg)
        for i in range(8):
            sched.submit(mk_req(i, cfg, plen=40, glen=16))
        peak = 0
        it = 0
        while sched.has_work and it < 400:
            plan = sched.plan(now=1e9)
            peak = max(peak, len(sched.running))
            for r in plan.refresh + plan.reuse:
                blk = r.block_tokens().copy()
                masked = np.where(blk == r.mask_id)[0]
                if masked.size:
                    blk[masked[0]] = 1
                r.advance(blk, now=it)
                if r.state == State.FINISHED:
                    sched.finish(r)
            it += 1
        return peak

    p_phase = peak_concurrency(PhaseMultiplexedScheduler)
    p_req = peak_concurrency(RequestLevelScheduler)
    assert p_phase > p_req, (p_phase, p_req)


@pytest.mark.parametrize("klass", [PhaseMultiplexedScheduler,
                                   RequestLevelScheduler])
def test_oversized_head_does_not_block_queue(klass):
    """Head-of-line fix: a never-admittable request at the FRONT of the
    waiting queue is rejected with a structured outcome in the same plan()
    call that admits the traffic behind it — previously the FCFS admission
    loop broke on the head and starved everything forever."""
    cfg = mk_cfg(max_num_batched_tokens=64)
    sched = klass(cfg)
    bad = mk_req(0, cfg, plen=56, glen=16)   # refresh cost 72 > budget 64
    good = [mk_req(i, cfg, plen=8, glen=8) for i in range(1, 4)]
    sched.submit(bad)
    for r in good:
        sched.submit(r)
    plan = sched.plan(now=0.0)
    assert bad in plan.rejected
    assert bad.state == State.REJECTED
    assert bad.outcome == Outcome.REJECTED_OVERSIZED
    assert "max_num_batched_tokens" in bad.error
    assert plan.admitted, "traffic behind the bad head must admit this iter"
    drain(sched, cfg)
    assert all(r.state == State.FINISHED for r in good)


@pytest.mark.parametrize("klass", [PhaseMultiplexedScheduler,
                                   RequestLevelScheduler])
def test_expired_head_does_not_block_queue(klass):
    """Same head-of-line property for deadline expiry: a dead waiter at the
    front is shed, not planned, and the queue behind it keeps moving."""
    cfg = mk_cfg()
    sched = klass(cfg)
    dead = mk_req(0, cfg, plen=8, glen=8)
    dead.deadline = 0.5
    live = mk_req(1, cfg, plen=8, glen=8)
    sched.submit(dead)
    sched.submit(live)
    plan = sched.plan(now=1.0)                # past dead's deadline
    assert dead in plan.shed
    assert dead.state == State.SHED
    assert dead.outcome == Outcome.SHED_DEADLINE
    assert live in plan.admitted
    drain(sched, cfg)
    assert live.state == State.FINISHED
