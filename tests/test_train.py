"""Training substrate: loss descent, fault tolerance, compression, elastic
restore, data pipeline determinism."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.base import TrainConfig
from repro.data.pipeline import Prefetcher, synthetic_batch
from repro.train import checkpoint as ckpt
from repro.train.optimizer import compress_grads, global_norm
from repro.train.train_loop import Trainer

CFG = reduced(ARCHS["llada-8b"])
TC = TrainConfig(microbatches=2, loss_chunk=64, warmup_steps=3)
DATA = lambda s: synthetic_batch(CFG, 4, 48, s, seed=11)


def test_loss_decreases():
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(CFG, TC, d, 4, 48, total_steps=40, ckpt_every=50)
        logs = tr.run(10, DATA)
        assert logs[-1]["loss"] < logs[0]["loss"]


def test_crash_resume_continuity():
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(CFG, TC, d, 4, 48, total_steps=40, ckpt_every=4)
        tr.run(8, DATA)
        with pytest.raises(RuntimeError, match="simulated node failure"):
            tr.run(8, DATA, crash_at=tr.start_step + 2)
        tr2 = Trainer(CFG, TC, d, 4, 48, total_steps=40, ckpt_every=4)
        assert tr2.start_step == 8   # resumed at the last checkpoint
        assert tr2.events.restarts == 1
        logs = tr2.run(4, DATA)
        assert np.isfinite(logs[-1]["loss"])


def test_checkpoint_roundtrip_exact():
    with tempfile.TemporaryDirectory() as d:
        state = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
                 "opt": {"m": jnp.ones((3, 4)) * 0.5, "step": jnp.int32(7)}}
        t = ckpt.save(d, 3, state, async_io=False)
        step, restored = ckpt.restore(d)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(state["params"]["w"]))
        assert int(restored["opt"]["step"]) == 7


def test_checkpoint_gc_and_atomicity():
    with tempfile.TemporaryDirectory() as d:
        for s in range(6):
            ckpt.save(d, s, {"x": jnp.zeros(2)}, keep=2, async_io=False)
        names = sorted(os.listdir(d))
        assert names == ["ckpt_00000004", "ckpt_00000005"]
        assert not any(n.endswith(".tmp") for n in names)
        assert ckpt.latest_step(d) == 5


def test_elastic_restore_shardings():
    """Checkpoints are mesh-independent: restore with explicit (single-
    device) shardings — the same path reshapes onto any new mesh."""
    with tempfile.TemporaryDirectory() as d:
        state = {"w": jnp.arange(16.0).reshape(4, 4)}
        ckpt.save(d, 1, state, async_io=False)
        sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
        _, restored = ckpt.restore(d, shardings={"w": sh})
        assert restored["w"].sharding == sh
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(state["w"]))


@pytest.mark.parametrize("mode", ["none", "bf16", "int8"])
def test_grad_compression_runs_and_is_close(mode):
    g = {"a": jnp.linspace(-1, 1, 64).reshape(8, 8),
         "b": jnp.ones((4,)) * 3.0}
    gc = compress_grads(g, mode)
    err = float(global_norm(jax.tree.map(
        lambda x, y: x - y.astype(x.dtype), g, gc)))
    base = float(global_norm(g))
    assert err <= (0.05 * base if mode != "none" else 1e-9)


def test_compressed_training_descends():
    tc = TrainConfig(microbatches=2, loss_chunk=64, warmup_steps=3,
                     grad_compression="bf16")
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(CFG, tc, d, 4, 48, total_steps=40, ckpt_every=50)
        logs = tr.run(8, DATA)
        assert logs[-1]["loss"] < logs[0]["loss"]


def test_pipeline_determinism_and_prefetch():
    a = synthetic_batch(CFG, 4, 32, step=5, seed=3)
    b = synthetic_batch(CFG, 4, 32, step=5, seed=3)
    c = synthetic_batch(CFG, 4, 32, step=6, seed=3)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.max() < CFG.vocab_size - 1   # mask id never in data
    pf = Prefetcher(lambda s: synthetic_batch(CFG, 2, 16, s, seed=1),
                    start_step=0, depth=2)
    try:
        x0 = next(pf)
        assert np.array_equal(x0, synthetic_batch(CFG, 2, 16, 0, seed=1))
    finally:
        pf.close()


def test_straggler_detection():
    import time
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(CFG, TC, d, 4, 48, total_steps=40, ckpt_every=50,
                     straggler_factor=2.5)
        slow = {"hit": False}

        def data(s):
            if s == 8 and not slow["hit"]:
                slow["hit"] = True
                time.sleep(1.0)      # simulated slow node
            return DATA(s)

        tr.run(10, data)
        assert any(e["step"] == 8 for e in tr.events.stragglers)
