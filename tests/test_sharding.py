"""Sharding-rule validity + per-device memory-plan invariants.

``Rules`` must emit placeable specs for ANY (heads, mesh) combination —
jax rejects uneven shards, so every sharded dim has to divide exactly
(non-divisible dims replicate; KV-head non-divisibility engages the
retained-length fallback) — and the ``Rules.cache`` spec trees must match
the actual cache pytrees the backbone emits (what the sharded ``KVPool``
allocates from). The per-device ``plan_memory`` arithmetic mirrors the same
divisibility laws, so its capacity-coupling invariant is tested here too.
"""
import dataclasses
import functools

import jax
import pytest

from tests._hyp_compat import given, settings, st

from repro.configs import ARCHS, get_config, reduced
from repro.configs.base import ServeConfig
from repro.launch.mesh import SimMesh, axis_size
from repro.launch.sharding import Rules
from repro.models import backbone as BB
from repro.models import transformer as T

FAMILY_ARCHS = ("llada-8b", "mamba2-130m", "zamba2-7b")


def _spec_leaves(shapes, specs):
    """(shape-leaf, spec) pairs with PartitionSpecs kept atomic."""
    s_leaves, treedef = jax.tree.flatten(shapes)
    return list(zip(s_leaves, treedef.flatten_up_to(specs)))


def _assert_valid(mesh, leaf, spec, where=""):
    """The placeability law: len(spec) == ndim, each mesh axis used at most
    once, and every sharded dim divisible by its combined shard count."""
    spec = tuple(spec)
    assert len(spec) <= leaf.ndim, (where, spec, leaf.shape)
    used = []
    for dim, entry in zip(leaf.shape, spec + (None,) * leaf.ndim):
        axes = (entry,) if isinstance(entry, str) else tuple(entry or ())
        shards = 1
        for a in axes:
            assert a not in used, (where, spec, "axis reused")
            used.append(a)
            shards *= axis_size(mesh, a)
        assert shards <= 1 or (dim % shards == 0 and dim >= shards), \
            (where, spec, leaf.shape, f"dim {dim} not divisible by {shards}")


MESHES = ((1, 1), (1, 2), (2, 2), (1, 3), (2, 4), (1, 16), (2, 2, 2))


@settings(max_examples=30, deadline=None)
@given(arch=st.sampled_from(FAMILY_ARCHS + ("gemma-2b", "internvl2-76b")),
       mesh_i=st.integers(0, len(MESHES) - 1),
       n_heads=st.sampled_from((1, 2, 3, 4, 6, 8)),
       kv_div=st.sampled_from((1, 2, 4)),
       train=st.booleans())
def test_rules_specs_always_placeable(arch, mesh_i, n_heads, kv_div, train):
    """Property: ANY (heads, mesh) combination yields placeable specs for
    params AND all three cache families — non-divisible dims replicated."""
    kv = max(1, n_heads // kv_div)
    if n_heads % kv:
        kv = n_heads
    cfg = reduced(ARCHS[arch], n_heads=n_heads, n_kv_heads=kv)
    mesh = SimMesh(MESHES[mesh_i])
    rules = Rules(cfg, mesh, train=train)
    shapes = jax.eval_shape(functools.partial(BB.init_params, cfg),
                            jax.random.PRNGKey(0))
    for leaf, spec in _spec_leaves(shapes, rules.params(shapes)):
        _assert_valid(mesh, leaf, spec, where="params")
    for batch in (1, 5, 8):
        for retain in (24, 64, 63):
            cache_shapes = _analytic_cache_shapes(cfg, batch, retain)
            specs = rules.cache(batch, retain)
            for leaf, spec in _spec_leaves(cache_shapes, specs):
                _assert_valid(mesh, leaf, spec, where=f"cache r={retain}")


def _analytic_cache_shapes(cfg, batch, retain):
    """Family cache pytree, shape-only — the SAME shape model the profiler
    bills per-device slot bytes with (no second copy to drift; anchored
    against the real ``eval_shape`` tree in
    ``test_cache_specs_match_backbone_cache_structure``)."""
    from repro.core.budgeting import _slot_cache_shapes
    return _slot_cache_shapes(cfg, ServeConfig(dtype=cfg.dtype), retain,
                              batch=batch)


def _cache_shapes(cfg, batch, retain):
    """The REAL cache pytree (shape-only) a Refresh step emits — what the
    sharded KVPool allocates from, so ``Rules.cache`` must match it."""
    ctx = T.ServeContext(block_size=8, retain=retain, kernel_size=3,
                         selection="head", q_chunk=64, max_seq_len=64)
    S = 64
    out = jax.eval_shape(
        lambda p, t, bs: BB.serve_refresh(
            p, cfg, t, bs, ctx,
            frontend=(jax.ShapeDtypeStruct(
                (batch, cfg.frontend_len, cfg.frontend_dim), "float32")
                if cfg.frontend_dim else None)),
        jax.eval_shape(functools.partial(BB.init_params, cfg),
                       jax.random.PRNGKey(0)),
        jax.ShapeDtypeStruct((batch, S), "int32"),
        jax.ShapeDtypeStruct((batch,), "int32"))
    return out.cache


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_cache_specs_match_backbone_cache_structure(arch):
    """``Rules.cache`` emits the exact pytree structure of each family's
    cache (PackedKV / SSMCache / HybridCache) with one spec entry per dim —
    the contract the sharded KVPool's tree_map allocation relies on."""
    from jax.sharding import PartitionSpec
    cfg = reduced(ARCHS[arch])
    rules = Rules(cfg, SimMesh((1, 2)), train=False)
    cache_shapes = _cache_shapes(cfg, batch=4, retain=24)
    specs = rules.cache(4, 24)
    assert jax.tree.structure(cache_shapes) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, PartitionSpec))
    for leaf, spec in _spec_leaves(cache_shapes, specs):
        assert len(tuple(spec)) == leaf.ndim, (arch, spec, leaf.shape)
    # the analytic shape model the property test samples from must agree
    # with the real backbone cache tree
    analytic = _analytic_cache_shapes(cfg, batch=4, retain=24)
    assert jax.tree.structure(analytic) == jax.tree.structure(cache_shapes)
    assert [tuple(a.shape) for a in jax.tree.leaves(analytic)] \
        == [tuple(b.shape) for b in jax.tree.leaves(cache_shapes)]


def test_retained_length_fallback_engages_on_mqa():
    """KV heads not divisible (MQA K=1 on model=2) -> heads replicated and
    the retained-length axis picks up the model sharding when divisible,
    stays replicated otherwise."""
    cfg = reduced(ARCHS["gemma-2b"])     # MQA: n_kv_heads == 1
    assert cfg.n_kv_heads == 1
    rules = Rules(cfg, SimMesh((1, 2)), train=False)
    kv = rules.packed_kv(batch=5, retain=64)      # batch%1==0 -> b over data
    assert tuple(kv.k)[2] is None                 # K replicated
    assert "model" in tuple(tuple(kv.k)[3] or ()), kv.k   # R sharded
    kv_odd = rules.packed_kv(batch=5, retain=63)  # 63 % 2 != 0
    assert tuple(kv_odd.k)[3] in (None, ()), kv_odd.k     # replicated


def test_divisible_heads_shard_over_model():
    cfg = reduced(ARCHS["llada-8b"])              # reduced: 4 KV heads
    rules = Rules(cfg, SimMesh((1, 2)), train=False)
    kv = rules.packed_kv(batch=4, retain=64)
    assert tuple(kv.k)[2] == "model"


# ---------------------------------------------------------------------------
# per-device memory planning (the §4.2-4.3 coupling on an N-device mesh)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", FAMILY_ARCHS)
@pytest.mark.parametrize("mesh_shape", [(1, 2), (1, 4)])
def test_plan_memory_per_device_capacity_coupling(arch, mesh_shape):
    """On a simulated N-device mesh the profiler must bill strictly smaller
    per-device weight + KV-slot bytes than one device and convert the freed
    headroom into at least as many (here: strictly more) slots."""
    from repro.core.budgeting import plan_memory
    cfg = get_config(arch)
    base = ServeConfig(max_num_batched_tokens=4000, max_num_logits=2048,
                       max_seq_len=2048, max_slots=1 << 20)
    hbm = 48 << 30
    p1 = plan_memory(cfg, base, hbm)
    pn = plan_memory(cfg, dataclasses.replace(base, mesh_shape=mesh_shape),
                     hbm)
    assert pn.mesh_devices == mesh_shape[0] * mesh_shape[1]
    assert pn.weights_bytes < p1.weights_bytes
    assert pn.slot_bytes < p1.slot_bytes
    assert pn.kv_pool_bytes >= p1.kv_pool_bytes
    assert pn.max_slots > p1.max_slots, (p1.summary(), pn.summary())


def test_plan_memory_no_mesh_equals_1x1_mesh():
    from repro.core.budgeting import plan_memory
    cfg = get_config("llada-8b")
    base = ServeConfig(max_num_batched_tokens=4000, max_seq_len=2048,
                       max_slots=64)
    p0 = plan_memory(cfg, base, 24 << 30)
    p1 = plan_memory(cfg, dataclasses.replace(base, mesh_shape=(1, 1)),
                     24 << 30)
    assert (p0.weights_bytes, p0.slot_bytes, p0.max_slots) \
        == (p1.weights_bytes, p1.slot_bytes, p1.max_slots)


def test_plan_memory_data_axis_replica_slots():
    """The slot pool shards its slot axis over ``data``: a (2, m) mesh
    carries 2 independent replica streams, so global slot capacity must be
    >= 2x the (1, m) plan (per-device bytes are identical — the data axis
    replicates weights at serve time)."""
    from repro.core.budgeting import plan_memory
    cfg = get_config("llada-8b")
    base = ServeConfig(max_num_batched_tokens=4000, max_num_logits=2048,
                       max_seq_len=2048, max_slots=1 << 20)
    hbm = 48 << 30
    p1 = plan_memory(cfg, dataclasses.replace(base, mesh_shape=(1, 2)), hbm)
    p2 = plan_memory(cfg, dataclasses.replace(base, mesh_shape=(2, 2)), hbm)
    assert p2.weights_bytes == p1.weights_bytes
    assert p2.slot_bytes == p1.slot_bytes
    assert p2.max_slots >= 2 * p1.max_slots, (p1.summary(), p2.summary())


# ---------------------------------------------------------------------------
# Pallas kernel partitioning law (kernels × TP; see kernels.ops)
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(arch=st.sampled_from(FAMILY_ARCHS),
       mesh_i=st.integers(0, len(MESHES) - 1),
       n_heads=st.sampled_from((1, 2, 3, 4, 6, 8)),
       kv_div=st.sampled_from((1, 2, 4)),
       flash=st.booleans(),
       fused=st.booleans())
def test_kernel_partition_plan_never_silently_falls_back(
        arch, mesh_i, n_heads, kv_div, flash, fused):
    """Property: ANY (heads, vocab) × mesh combination with kernels enabled
    either yields a full per-shard partition plan (every enabled kernel dim
    divides the model axis) or raises the divisibility ValueError — there is
    no middle ground where a kernel would silently run replicated."""
    from repro.launch.sharding import kernel_partition_plan
    kv = max(1, n_heads // kv_div)
    if n_heads % kv:
        kv = n_heads
    cfg = reduced(ARCHS[arch], n_heads=n_heads, n_kv_heads=kv)
    serve = ServeConfig(
        mesh_shape=MESHES[mesh_i], use_flash_kernel=flash,
        logit_mode="fused" if fused else "chunked")
    m = serve.mesh_model
    dims = {}
    if flash:
        if cfg.has_attention:
            dims["n_heads"] = cfg.n_heads
            dims["n_kv_heads"] = cfg.n_kv_heads
        if cfg.ssm_state:
            dims["ssm_heads"] = cfg.ssm_heads
    if fused:
        dims["vocab_size"] = cfg.vocab_size
    divisible = all(v % m == 0 for v in dims.values())
    if divisible:
        plan = kernel_partition_plan(cfg, serve)
        assert set(plan) == set(dims)
        assert all(s == m for s in plan.values())
    else:
        with pytest.raises(ValueError, match="divide"):
            kernel_partition_plan(cfg, serve)
