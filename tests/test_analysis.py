"""The invariant analyzer's own gate: rule fixtures (each rule fires exactly
once on a known violation), the repo lints clean, the grid audit classifies
divisible and indivisible (arch, mesh) combos correctly, and the retrace
sentinel proves zero post-warmup compilations on a full padded-path serve."""
import numpy as np
import pytest

from repro.analysis.lint import run_lint
from repro.analysis.rules import all_rules
from repro.analysis.trace_audit import run_grid_audit

# ---------------------------------------------------------------------------
# fixture sources: each contains EXACTLY ONE violation of its rule
# ---------------------------------------------------------------------------
VIOLATIONS = {
    "mesh-api": "from jax.sharding import PartitionSpec\n",
    "bare-jit": "import jax\nf = jax.jit(lambda x: x)\n",
    "host-sync": "import jax\n\n\ndef f(x):\n    return x.item()\n",
    "silent-fallback": ("def dispatch(serve, x):\n"
                        "    if serve.use_flash_kernel:\n"
                        "        x = x + 1\n"
                        "    return x\n"),
    # two annotated syncs: each line passes host-sync via its pragma, but
    # the function still stalls twice — multi-sync fires on the second.
    "multi-sync": ("import jax\n"
                   "\n"
                   "\n"
                   "def f(a, b):\n"
                   "    x = jax.device_get(a)  # lint: allow(host-sync)\n"
                   "    y = jax.device_get(b)  # lint: allow(host-sync)\n"
                   "    return x, y\n"),
    # the same buffer at the donated position 0 and again at position 1
    "donation": ("import jax\n"
                 "from repro import jax_compat as JC\n"
                 "g = JC.jit(lambda a, b: a + b, donate_argnums=(0,))\n"
                 "\n"
                 "\n"
                 "def f(x):\n"
                 "    return g(x, x)\n"),
}


def _lint_fixture(tmp_path, name, source):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True, exist_ok=True)
    (pkg / f"fixture_{name.replace('-', '_')}.py").write_text(source)
    return run_lint(root=tmp_path, rules=all_rules())


@pytest.mark.parametrize("rule", sorted(VIOLATIONS))
def test_rule_fires_exactly_once(tmp_path, rule):
    report = _lint_fixture(tmp_path, rule, VIOLATIONS[rule])
    assert [f.rule for f in report.findings] == [rule], report.findings


def test_pragma_suppresses(tmp_path):
    src = "import jax\nf = jax.jit(lambda x: x)  # lint: allow(bare-jit)\n"
    report = _lint_fixture(tmp_path, "pragma", src)
    assert report.ok
    assert [s["rule"] for s in report.suppressed] == ["bare-jit"]
    assert report.suppressed[0]["via"] == "pragma"


def test_accounted_dispatch_is_clean(tmp_path):
    src = ("def dispatch(serve, x):\n"
           "    if serve.use_flash_kernel:\n"
           "        _require_divisible('k', h=4)\n"
           "        x = x + 1\n"
           "    return x\n")
    report = _lint_fixture(tmp_path, "accounted", src)
    assert report.ok, report.findings


def test_single_annotated_sync_is_clean(tmp_path):
    """The engine's contract — ONE annotated device_get per function —
    passes both host-sync (pragma) and multi-sync (count == 1)."""
    src = ("import jax\n"
           "\n"
           "\n"
           "def f(a, b):\n"
           "    x, y = jax.device_get((a, b))  # lint: allow(host-sync)\n"
           "    return x, y\n")
    report = _lint_fixture(tmp_path, "one-sync", src)
    assert report.ok, report.findings


def test_donation_use_after_donate(tmp_path):
    """Reading a donated buffer after the call is flagged; re-binding it
    to the result (the idiomatic `buf = step(buf)`) is not."""
    src = ("import jax\n"
           "from repro import jax_compat as JC\n"
           "step = JC.jit(lambda a: a * 2, donate_argnums=(0,))\n"
           "\n"
           "\n"
           "def bad(buf):\n"
           "    out = step(buf)\n"
           "    return out + buf\n")
    report = _lint_fixture(tmp_path / "bad", "use-after", src)
    assert [f.rule for f in report.findings] == ["donation"], report.findings

    ok = ("import jax\n"
          "from repro import jax_compat as JC\n"
          "step = JC.jit(lambda a: a * 2, donate_argnums=(0,))\n"
          "\n"
          "\n"
          "def good(buf):\n"
          "    buf = step(buf)\n"
          "    return buf\n")
    report = _lint_fixture(tmp_path / "ok", "rebind", ok)
    assert report.ok, report.findings


def test_repo_lints_clean():
    """The codebase passes its own gate — CI runs this as
    ``python -m repro.analysis --strict``."""
    report = run_lint()
    assert report.ok, "\n".join(str(f) for f in report.findings)
    assert report.files_scanned > 50


# ---------------------------------------------------------------------------
# grid audit
# ---------------------------------------------------------------------------

def test_grid_audit_indivisible_is_expected_raise():
    """gemma-2b has n_kv_heads=1: a 2-way model axis CANNOT divide it —
    the audit must classify that as the documented raise, not a failure."""
    report = run_grid_audit(archs=["gemma-2b"], trace_stages=False)
    assert report.ok, [c.to_dict() for c in report.errors]
    by_mesh = {c.mesh: c for c in report.cells}
    assert by_mesh[(1, 1)].status == "ok"
    assert by_mesh[(2, 1)].status == "ok"      # pure data-parallel divides
    for mesh in ((1, 2), (2, 2)):
        cell = by_mesh[mesh]
        assert cell.status == "expected-raise", cell.to_dict()
        assert "n_kv_heads=1" in cell.detail


def test_grid_audit_divisible_arch_traces_everywhere():
    report = run_grid_audit(archs=["llada-8b"])
    assert report.ok, [c.to_dict() for c in report.errors]
    assert all(c.status == "ok" for c in report.cells)
    stages = report.stage_shapes["llada-8b"]
    assert set(stages) == {"refresh", "refresh_packed", "reuse",
                           "reuse_packed", "decode", "decode_packed"}
    for cell in report.cells:
        if cell.mesh[1] > 1:      # kernel dims actually split on the plan
            assert cell.plan and all(v == cell.mesh[1]
                                     for v in cell.plan.values())


# ---------------------------------------------------------------------------
# retrace sentinel
# ---------------------------------------------------------------------------

def test_jit_shim_counts_compiles():
    import jax.numpy as jnp

    from repro import jax_compat as JC
    from collections import Counter
    c = Counter()
    f = JC.jit(lambda x: x * 2, entry="t", counter=c)
    f(jnp.zeros((4,)))
    f(jnp.ones((4,)))              # same shape: cache hit, no retrace
    assert c["t"] == 1
    f(jnp.zeros((8,)))             # new shape: one more compile
    assert c["t"] == 2
    assert JC.compile_counts().get("t", 0) >= 2


def test_engine_zero_post_warmup_compiles():
    """The padded path's warmup doubling loops cover every pow2 bucket the
    runtime can request — a full serve trace after warmup must add ZERO
    compilations (the retrace budget docs/analysis.md holds at zero)."""
    from repro.analysis.retrace import check_engine
    from repro.configs import ARCHS, reduced
    from repro.configs.base import ServeConfig
    from repro.core.engine import Engine
    from repro.core.request import State

    serve = ServeConfig(max_num_batched_tokens=512, max_num_logits=64,
                        block_size=8, steps_per_block=8, max_seq_len=64,
                        max_slots=4, max_refresh_per_iter=2,
                        selection="head", scheduler="phase",
                        logit_mode="chunked")
    eng = Engine(reduced(ARCHS["llada-8b"]), serve, seed=0)
    eng.warmup()
    assert eng.stats.compiles_warmup > 0
    assert {"refresh", "reuse", "decode"} <= set(eng.stats.compile_counts)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, 100, int(rng.integers(8, 30))),
                       gen_len=16, rid=i) for i in range(5)]
    stats = eng.run()
    assert all(r.state == State.FINISHED for r in reqs)
    assert stats.compiles_post_warmup == 0, stats.compile_counts
    report = check_engine(eng, budget=0)
    assert report.ok, report.violations
    assert report.compiles_warmup == stats.compiles_warmup


def test_retrace_flags_unwarmed_engine():
    """Without warmup every compile bills post-warmup: the sentinel must
    refuse the trace rather than silently passing."""
    from repro.analysis.retrace import check_engine
    from repro.configs import ARCHS, reduced
    from repro.configs.base import ServeConfig
    from repro.core.engine import Engine

    serve = ServeConfig(max_num_batched_tokens=512, max_num_logits=64,
                        block_size=8, steps_per_block=8, max_seq_len=64,
                        max_slots=2, max_refresh_per_iter=1,
                        selection="head", scheduler="phase",
                        logit_mode="chunked")
    eng = Engine(reduced(ARCHS["llada-8b"]), serve, seed=0)
    eng.submit(np.arange(8), gen_len=8, rid=0)
    eng.run()
    report = check_engine(eng, budget=0)
    assert not report.ok
    assert any("warmup" in v for v in report.violations)
