"""Token-packed (varlen) Refresh path: kernel, model, engine, plan, budget.

The padded ``serve_refresh`` is the correctness oracle throughout — the
packed path must agree on block hidden states for random ragged batches and
must never fall back to a ``[B, max_seq_len]`` padded refresh dispatch.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.configs import ARCHS, reduced
from repro.configs.base import ServeConfig
from repro.core.engine import Engine
from repro.core.request import State
from repro.core.scheduler import PhaseMultiplexedScheduler
from repro.kernels import ops, ref
from repro.kernels.flash_varlen import PAD_SEG
from repro.models import backbone as BB
from repro.models import transformer as T

KEY = jax.random.PRNGKey(11)

SERVE = ServeConfig(max_num_batched_tokens=512, max_num_logits=64,
                    block_size=8, steps_per_block=8, max_seq_len=128,
                    max_slots=8, max_refresh_per_iter=2,
                    selection="head", scheduler="phase", logit_mode="chunked",
                    varlen_pack=True, token_bucket=64)

# reduced per-family configs exercised by the packed/padded agreement tests
# (≥2 model families; moe capacity is made non-dropping so padded-batch pad
# rows cannot perturb expert routing of real tokens)
FAMS = {
    "llada-8b": {},
    "phi3.5-moe-42b-a6.6b": {"capacity_factor": 4.0},
    "gemma2-27b": {},
}


def _ragged_stream(lens, max_seq_len, vocab, seed=0, bucket=64):
    """Build padded-batch and packed-stream views of one ragged batch."""
    rng = np.random.default_rng(seed)
    B = len(lens)
    toks = [rng.integers(0, vocab - 1, L).astype(np.int32) for L in lens]
    tok_pad = np.zeros((B, max_seq_len), np.int32)
    valid_pad = np.zeros((B, max_seq_len), bool)
    for j, t in enumerate(toks):
        tok_pad[j, : len(t)] = t
        valid_pad[j, : len(t)] = True
    t_real = int(sum(lens))
    tp = -(-t_real // bucket) * bucket
    flat = np.zeros(tp, np.int32)
    pos = np.zeros(tp, np.int32)
    seg = np.full(tp, PAD_SEG, np.int32)
    val = np.zeros(tp, bool)
    cu = np.full(B, max(0, tp - 1), np.int32)
    sl = np.zeros(B, np.int32)
    off = 0
    for j, t in enumerate(toks):
        L = len(t)
        flat[off: off + L] = t
        pos[off: off + L] = np.arange(L)
        seg[off: off + L] = j
        val[off: off + L] = True
        cu[j] = off
        sl[j] = L
        off += L
    return tok_pad, valid_pad, flat, pos, seg, val, cu, sl


# ---------------------------------------------------------------------------
# kernel: ragged flash attention vs the full-mask oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("softcap,window,is_local", [
    (0.0, 0, False), (25.0, 0, False), (0.0, 8, True)])
def test_flash_varlen_matches_ref(softcap, window, is_local):
    rng = np.random.default_rng(3)
    lens = rng.integers(5, 40, size=4)
    t_real = int(lens.sum())
    tp = -(-t_real // 64) * 64
    seg = np.full(tp, PAD_SEG, np.int32)
    pos = np.zeros(tp, np.int32)
    valid = np.zeros(tp, bool)
    off = 0
    for i, L in enumerate(lens):
        seg[off: off + L] = i
        pos[off: off + L] = np.arange(L)
        valid[off: off + L] = True
        off += L
    H, K, dh = 4, 2, 16
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (tp, H, dh))
    k = jax.random.normal(kk, (tp, K, dh))
    v = jax.random.normal(kv, (tp, K, dh))
    out = ops.flash_varlen_attention(
        q, k, v, seg_ids=jnp.asarray(seg), positions=jnp.asarray(pos),
        kv_valid=jnp.asarray(valid), softcap=softcap, window=window,
        is_local=is_local, q_tile=16, kv_tile=32)
    out_r = ref.varlen_attention(
        q, k, v, jnp.asarray(seg), jnp.asarray(pos), jnp.asarray(valid),
        softcap=softcap, window=window, is_local=is_local)
    np.testing.assert_allclose(np.asarray(out)[valid],
                               np.asarray(out_r)[valid], atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999), q_tile=st.sampled_from([8, 16, 64]),
       kv_tile=st.sampled_from([16, 32, 64]))
def test_flash_varlen_tile_invariance(seed, q_tile, kv_tile):
    """Online accumulation + tile-skip must be invariant to tiling."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(3, 30, size=int(rng.integers(1, 5)))
    t_real = int(lens.sum())
    tp = -(-t_real // 64) * 64
    seg = np.full(tp, PAD_SEG, np.int32)
    pos = np.zeros(tp, np.int32)
    valid = np.zeros(tp, bool)
    off = 0
    for i, L in enumerate(lens):
        seg[off: off + L] = i
        pos[off: off + L] = np.arange(L)
        valid[off: off + L] = True
        off += L
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (tp, 4, 8))
    k = jax.random.normal(kk, (tp, 2, 8))
    v = jax.random.normal(kv, (tp, 2, 8))
    kw = dict(seg_ids=jnp.asarray(seg), positions=jnp.asarray(pos),
              kv_valid=jnp.asarray(valid))
    a = ops.flash_varlen_attention(q, k, v, q_tile=q_tile, kv_tile=kv_tile,
                                   **kw)
    b = ops.flash_varlen_attention(q, k, v, q_tile=64, kv_tile=64, **kw)
    np.testing.assert_allclose(np.asarray(a)[valid], np.asarray(b)[valid],
                               atol=1e-5)


# ---------------------------------------------------------------------------
# model: packed vs padded serve_refresh agreement (the oracle contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", list(FAMS))
@pytest.mark.parametrize("use_kernel", [False, True])
def test_packed_refresh_matches_padded(arch, use_kernel):
    cfg = reduced(ARCHS[arch], **FAMS[arch])
    params = BB.init_params(cfg, KEY)
    # the padded oracle always runs the chunked-jnp path; the packed side
    # optionally dispatches the Pallas varlen kernel (kernel-vs-jnp check)
    ctx = T.ServeContext(block_size=8, retain=24, q_chunk=32, max_seq_len=96)
    ctx_pk = dataclasses.replace(ctx, use_flash_refresh=use_kernel)
    rng = np.random.default_rng(7)
    for trial in range(2):
        lens = [int(x) for x in rng.integers(12, 96, size=3)]
        bstarts = np.array([max(0, L - 8 - int(rng.integers(0, max(1, L - 8))))
                            for L in lens], np.int32)
        bstarts = (bstarts // 8) * 8
        tok_pad, valid_pad, flat, pos, seg, val, cu, sl = _ragged_stream(
            lens, 96, cfg.vocab_size, seed=trial)
        out_pad = BB.serve_refresh(
            params, cfg, jnp.asarray(tok_pad), jnp.asarray(bstarts), ctx,
            token_valid=jnp.asarray(valid_pad))
        out_pk = BB.serve_refresh_packed(
            params, cfg, jnp.asarray(flat), jnp.asarray(pos),
            jnp.asarray(seg), jnp.asarray(val), jnp.asarray(cu),
            jnp.asarray(sl), jnp.asarray(bstarts), ctx_pk)
        np.testing.assert_allclose(
            np.asarray(out_pk.block_hidden, np.float32),
            np.asarray(out_pad.block_hidden, np.float32), atol=1e-4)
        # retained caches must agree too (pre-pool masking keeps selection
        # independent of batch composition; rare fp-tie flips aside, the
        # overwhelming majority of retained positions must match)
        pos_eq = (np.asarray(out_pk.cache.pos)
                  == np.asarray(out_pad.cache.pos)).mean()
        assert pos_eq > 0.99, pos_eq


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 500), n=st.integers(1, 4))
def test_packed_refresh_property_random_ragged(seed, n):
    """Property form: any ragged batch, any block offsets, dense family."""
    cfg = reduced(ARCHS["llada-8b"])
    params = BB.init_params(cfg, jax.random.PRNGKey(1))
    ctx = T.ServeContext(block_size=8, retain=16, q_chunk=32, max_seq_len=64)
    rng = np.random.default_rng(seed)
    lens = [int(x) for x in rng.integers(9, 64, size=n)]
    bstarts = np.array([int(rng.integers(0, L - 8)) for L in lens], np.int32)
    tok_pad, valid_pad, flat, pos, seg, val, cu, sl = _ragged_stream(
        lens, 64, cfg.vocab_size, seed=seed, bucket=32)
    out_pad = BB.serve_refresh(
        params, cfg, jnp.asarray(tok_pad), jnp.asarray(bstarts), ctx,
        token_valid=jnp.asarray(valid_pad))
    out_pk = BB.serve_refresh_packed(
        params, cfg, jnp.asarray(flat), jnp.asarray(pos), jnp.asarray(seg),
        jnp.asarray(val), jnp.asarray(cu), jnp.asarray(sl),
        jnp.asarray(bstarts), ctx)
    np.testing.assert_allclose(
        np.asarray(out_pk.block_hidden, np.float32),
        np.asarray(out_pad.block_hidden, np.float32), atol=1e-4)


def test_selection_ignores_foreign_neighbours():
    """A request's retained KV set must not depend on what it is packed
    with: rows past seq_len in the per-request gather view belong to the
    NEXT request, and the score max-pool must not leak their relevance into
    valid boundary tokens (scores are masked to -inf pre-pool)."""
    from repro.models.sparse_select import select_and_pack
    B, Sb, K, G, S, dh = 1, 4, 2, 2, 24, 8
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, Sb, K * G, dh))
    kf = jax.random.normal(ks[1], (B, S, K, dh))
    vf = jax.random.normal(ks[2], (B, S, K, dh))
    valid = jnp.zeros((B, S), bool).at[:, :16].set(True)   # tokens ≥16 foreign
    excl = ~valid
    kw = dict(retain=8, kernel_size=3, mode="head", exclude=excl,
              token_valid=valid)
    p1 = select_and_pack(q, kf, vf, **kw)
    # replace the foreign tail with adversarially-huge keys: selection of the
    # valid region must be bit-identical
    kf2 = kf.at[:, 16:].set(100.0 * jnp.abs(kf[:, 16:]) + 50.0)
    p2 = select_and_pack(q, kf2, vf, **kw)
    assert np.array_equal(np.asarray(p1.pos), np.asarray(p2.pos))
    assert np.array_equal(np.asarray(p1.valid), np.asarray(p2.valid))


def test_windowed_stream_attention_matches_plain():
    """The windowed jnp fallback (KV window = q_chunk + 2L) must be exact:
    build a stream long enough that windows genuinely truncate."""
    cfg = reduced(ARCHS["llada-8b"])
    rng = np.random.default_rng(9)
    S_max, c = 24, 16
    lens, total = [], 0
    while total < 200:
        L = int(rng.integers(6, S_max + 1))
        lens.append(L)
        total += L
    tp = -(-total // c) * c
    seg = np.full(tp, PAD_SEG, np.int32)
    pos = np.zeros(tp, np.int32)
    val = np.zeros(tp, bool)
    off = 0
    for i, L in enumerate(lens):
        seg[off: off + L] = i
        pos[off: off + L] = np.arange(L)
        val[off: off + L] = True
        off += L
    H, K, dh = 4, 2, 16
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (1, tp, H, dh))
    k = jax.random.normal(kk, (1, tp, K, dh))
    v = jax.random.normal(kv, (1, tp, K, dh))
    serve = T.ServeContext(block_size=8, retain=8, q_chunk=c,
                           max_seq_len=S_max)
    assert c + 2 * S_max < tp   # windows actually truncate
    win = T._attend_packed_stream(
        q, k, v, jnp.asarray(pos)[None], jnp.asarray(seg)[None],
        jnp.asarray(val)[None], cfg, jnp.asarray(False), serve)
    ref_out = ref.varlen_attention(
        q[0], k[0], v[0], jnp.asarray(seg), jnp.asarray(pos),
        jnp.asarray(val))
    np.testing.assert_allclose(np.asarray(win)[0][val],
                               np.asarray(ref_out)[val], atol=2e-5)


def test_packed_refresh_rejects_ssm():
    cfg = reduced(ARCHS["mamba2-130m"])
    params = BB.init_params(cfg, KEY)
    ctx = T.ServeContext(block_size=8, retain=16, q_chunk=32, max_seq_len=64)
    z = jnp.zeros((32,), jnp.int32)
    with pytest.raises(NotImplementedError):
        BB.serve_refresh_packed(params, cfg, z, z, z, jnp.ones((32,), bool),
                                z[:1], z[:1], z[:1], ctx)


# ---------------------------------------------------------------------------
# engine: the packed fast path never issues a padded refresh
# ---------------------------------------------------------------------------

def _serve_engine(serve, n=5, seed=0, arch="llada-8b", forbid_padded=False):
    cfg = reduced(ARCHS[arch])
    eng = Engine(cfg, serve, seed=seed)
    if forbid_padded:
        def _boom(chunk):
            raise AssertionError("padded [B, max_seq_len] refresh on the "
                                 "packed path")
        eng._run_refresh = _boom
    rng = np.random.default_rng(seed)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size - 1,
                                    int(rng.integers(8, 40))),
                       gen_len=16, arrival=0.0, rid=i)
            for i in range(n)]
    stats = eng.run()
    return eng, reqs, stats


def test_engine_packed_no_padded_refresh_call():
    eng, reqs, stats = _serve_engine(SERVE, forbid_padded=True)
    assert all(r.state == State.FINISHED for r in reqs)
    assert all((r.output_tokens() != eng.mask_id).all() for r in reqs)
    assert stats.padded_refresh_calls == 0
    assert stats.packed_refresh_calls > 0
    # executed tokens within one token-bucket of Σ total_len per dispatch
    assert stats.refresh_tokens_exec >= stats.refresh_tokens_real
    assert stats.refresh_tokens_exec < stats.refresh_tokens_real + \
        SERVE.token_bucket * stats.packed_refresh_calls


def test_engine_packed_padded_same_totals():
    _, r_pk, s_pk = _serve_engine(SERVE, seed=3)
    _, r_pd, s_pd = _serve_engine(
        dataclasses.replace(SERVE, varlen_pack=False), seed=3)
    assert s_pk.committed_tokens == s_pd.committed_tokens
    assert all(r.state == State.FINISHED for r in r_pk + r_pd)
    # the padded oracle pays strictly more executed tokens on ragged work
    assert s_pk.refresh_tokens_exec < s_pd.refresh_tokens_exec
    assert s_pk.refresh_tokens_real == s_pd.refresh_tokens_real


def test_engine_packed_flash_kernel_path():
    serve = dataclasses.replace(SERVE, use_flash_kernel=True)
    _, reqs, stats = _serve_engine(serve, n=3, forbid_padded=True)
    assert all(r.state == State.FINISHED for r in reqs)
    assert stats.packed_refresh_calls > 0


def test_engine_ssm_falls_back_to_padded_oracle():
    _, reqs, stats = _serve_engine(SERVE, n=2, arch="mamba2-130m")
    assert all(r.state == State.FINISHED for r in reqs)
    assert stats.packed_refresh_calls == 0
    assert stats.padded_refresh_calls > 0


# ---------------------------------------------------------------------------
# plan: packed layout + query-token invariant
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 10), budget=st.integers(64, 512),
       seed=st.integers(0, 99))
def test_packed_plan_layout_and_invariant(n, budget, seed):
    from repro.core.request import Request
    cfg = dataclasses.replace(SERVE, max_num_batched_tokens=budget)
    sched = PhaseMultiplexedScheduler(cfg)
    rng = np.random.default_rng(seed)
    for i in range(n):
        plen = int(rng.integers(4, 48))
        if plen + 16 + 8 > cfg.max_seq_len or plen + 16 > budget:
            plen = 8
        sched.submit(Request(rid=i, prompt=np.zeros(plen, np.int32),
                             gen_len=16, arrival=0.0, cfg=cfg, mask_id=255))
    for _ in range(3):
        plan = sched.plan(now=1e9)
        cu = plan.refresh_cu_seqlens()
        assert cu[0] == 0 and cu[-1] == plan.refresh_total_tokens
        assert np.all(np.diff(cu) > 0) or len(plan.refresh) == 0
        assert list(np.diff(cu)) == plan.refresh_token_counts
        # query-token invariant holds for the packed layout too
        assert plan.refresh_total_tokens <= plan.query_tokens <= budget
        for r in plan.refresh + plan.reuse:
            blk = r.block_tokens().copy()
            blk[:] = 1
            r.advance(blk, now=0.0)
            if r.state == State.FINISHED:
                sched.finish(r)


# ---------------------------------------------------------------------------
# budgeting: packed activation accounting buys KV slots
# ---------------------------------------------------------------------------

def test_budgeting_packed_tokens_buy_slots():
    from repro.configs import get_config
    from repro.core.budgeting import max_exec_tokens, plan_memory
    cfg = get_config("llada-8b")
    base = ServeConfig(max_num_batched_tokens=4000, max_num_logits=2048,
                       max_seq_len=2048, max_slots=256, max_refresh_per_iter=4,
                       logit_mode="chunked")
    packed = dataclasses.replace(base, varlen_pack=True)
    assert max_exec_tokens(packed, cfg) < max_exec_tokens(base, cfg)
    # families the engine cannot pack keep the padded reservation even under
    # varlen_pack (the padded-oracle fallback executes the full rectangle)
    from repro.configs import get_config as _gc
    ssm_cfg = _gc("mamba2-130m")
    assert max_exec_tokens(packed, ssm_cfg) == max_exec_tokens(base, ssm_cfg)
    p_pad = plan_memory(cfg, base, 24 << 30)
    p_pk = plan_memory(cfg, packed, 24 << 30)
    assert p_pk.activation_bytes < p_pad.activation_bytes
    assert p_pk.max_slots >= p_pad.max_slots
    assert p_pk.kv_pool_bytes > p_pad.kv_pool_bytes