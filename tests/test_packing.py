"""Whole-iteration token packing: kernels, model, engine, plan, budget.

The padded paths (``serve_refresh`` / ``serve_reuse`` / ``decode_tokens``)
are the correctness oracles throughout — every packed stage must agree on
random ragged batches and the packed engine must never fall back to a
pow2-padded dispatch for any stage (Refresh, Reuse, or the logit stage).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.configs import ARCHS, reduced
from repro.configs.base import ServeConfig
from repro.core.engine import Engine
from repro.core.request import State
from repro.core.scheduler import PhaseMultiplexedScheduler
from repro.kernels import ops, ref
from repro.kernels.flash_varlen import PAD_SEG
from repro.models import backbone as BB
from repro.models import transformer as T

KEY = jax.random.PRNGKey(11)

SERVE = ServeConfig(max_num_batched_tokens=512, max_num_logits=64,
                    block_size=8, steps_per_block=8, max_seq_len=128,
                    max_slots=8, max_refresh_per_iter=2,
                    selection="head", scheduler="phase", logit_mode="chunked",
                    varlen_pack=True, token_bucket=64)

# reduced per-family configs exercised by the packed/padded agreement tests
# (≥2 model families; moe capacity is made non-dropping so padded-batch pad
# rows cannot perturb expert routing of real tokens)
FAMS = {
    "llada-8b": {},
    "phi3.5-moe-42b-a6.6b": {"capacity_factor": 4.0},
    "gemma2-27b": {},
}


def _ragged_stream(lens, max_seq_len, vocab, seed=0, bucket=64):
    """Build padded-batch and packed-stream views of one ragged batch."""
    rng = np.random.default_rng(seed)
    B = len(lens)
    toks = [rng.integers(0, vocab - 1, L).astype(np.int32) for L in lens]
    tok_pad = np.zeros((B, max_seq_len), np.int32)
    valid_pad = np.zeros((B, max_seq_len), bool)
    for j, t in enumerate(toks):
        tok_pad[j, : len(t)] = t
        valid_pad[j, : len(t)] = True
    t_real = int(sum(lens))
    tp = -(-t_real // bucket) * bucket
    flat = np.zeros(tp, np.int32)
    pos = np.zeros(tp, np.int32)
    seg = np.full(tp, PAD_SEG, np.int32)
    val = np.zeros(tp, bool)
    cu = np.full(B, max(0, tp - 1), np.int32)
    sl = np.zeros(B, np.int32)
    off = 0
    for j, t in enumerate(toks):
        L = len(t)
        flat[off: off + L] = t
        pos[off: off + L] = np.arange(L)
        seg[off: off + L] = j
        val[off: off + L] = True
        cu[j] = off
        sl[j] = L
        off += L
    return tok_pad, valid_pad, flat, pos, seg, val, cu, sl


# ---------------------------------------------------------------------------
# kernel: ragged flash attention vs the full-mask oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("softcap,window,is_local", [
    (0.0, 0, False), (25.0, 0, False), (0.0, 8, True)])
def test_flash_varlen_matches_ref(softcap, window, is_local):
    rng = np.random.default_rng(3)
    lens = rng.integers(5, 40, size=4)
    t_real = int(lens.sum())
    tp = -(-t_real // 64) * 64
    seg = np.full(tp, PAD_SEG, np.int32)
    pos = np.zeros(tp, np.int32)
    valid = np.zeros(tp, bool)
    off = 0
    for i, L in enumerate(lens):
        seg[off: off + L] = i
        pos[off: off + L] = np.arange(L)
        valid[off: off + L] = True
        off += L
    H, K, dh = 4, 2, 16
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (tp, H, dh))
    k = jax.random.normal(kk, (tp, K, dh))
    v = jax.random.normal(kv, (tp, K, dh))
    out = ops.flash_varlen_attention(
        q, k, v, seg_ids=jnp.asarray(seg), positions=jnp.asarray(pos),
        kv_valid=jnp.asarray(valid), softcap=softcap, window=window,
        is_local=is_local, q_tile=16, kv_tile=32)
    out_r = ref.varlen_attention(
        q, k, v, jnp.asarray(seg), jnp.asarray(pos), jnp.asarray(valid),
        softcap=softcap, window=window, is_local=is_local)
    np.testing.assert_allclose(np.asarray(out)[valid],
                               np.asarray(out_r)[valid], atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999), q_tile=st.sampled_from([8, 16, 64]),
       kv_tile=st.sampled_from([16, 32, 64]))
def test_flash_varlen_tile_invariance(seed, q_tile, kv_tile):
    """Online accumulation + tile-skip must be invariant to tiling."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(3, 30, size=int(rng.integers(1, 5)))
    t_real = int(lens.sum())
    tp = -(-t_real // 64) * 64
    seg = np.full(tp, PAD_SEG, np.int32)
    pos = np.zeros(tp, np.int32)
    valid = np.zeros(tp, bool)
    off = 0
    for i, L in enumerate(lens):
        seg[off: off + L] = i
        pos[off: off + L] = np.arange(L)
        valid[off: off + L] = True
        off += L
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (tp, 4, 8))
    k = jax.random.normal(kk, (tp, 2, 8))
    v = jax.random.normal(kv, (tp, 2, 8))
    kw = dict(seg_ids=jnp.asarray(seg), positions=jnp.asarray(pos),
              kv_valid=jnp.asarray(valid))
    a = ops.flash_varlen_attention(q, k, v, q_tile=q_tile, kv_tile=kv_tile,
                                   **kw)
    b = ops.flash_varlen_attention(q, k, v, q_tile=64, kv_tile=64, **kw)
    np.testing.assert_allclose(np.asarray(a)[valid], np.asarray(b)[valid],
                               atol=1e-5)


# ---------------------------------------------------------------------------
# model: packed vs padded serve_refresh agreement (the oracle contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", list(FAMS))
@pytest.mark.parametrize("use_kernel", [False, True])
def test_packed_refresh_matches_padded(arch, use_kernel):
    cfg = reduced(ARCHS[arch], **FAMS[arch])
    params = BB.init_params(cfg, KEY)
    # the padded oracle always runs the chunked-jnp path; the packed side
    # optionally dispatches the Pallas varlen kernel (kernel-vs-jnp check)
    ctx = T.ServeContext(block_size=8, retain=24, q_chunk=32, max_seq_len=96)
    ctx_pk = dataclasses.replace(ctx, use_flash_refresh=use_kernel)
    rng = np.random.default_rng(7)
    for trial in range(2):
        lens = [int(x) for x in rng.integers(12, 96, size=3)]
        bstarts = np.array([max(0, L - 8 - int(rng.integers(0, max(1, L - 8))))
                            for L in lens], np.int32)
        bstarts = (bstarts // 8) * 8
        tok_pad, valid_pad, flat, pos, seg, val, cu, sl = _ragged_stream(
            lens, 96, cfg.vocab_size, seed=trial)
        out_pad = BB.serve_refresh(
            params, cfg, jnp.asarray(tok_pad), jnp.asarray(bstarts), ctx,
            token_valid=jnp.asarray(valid_pad))
        out_pk = BB.serve_refresh_packed(
            params, cfg, jnp.asarray(flat), jnp.asarray(pos),
            jnp.asarray(seg), jnp.asarray(val), jnp.asarray(cu),
            jnp.asarray(sl), jnp.asarray(bstarts), ctx_pk)
        np.testing.assert_allclose(
            np.asarray(out_pk.block_hidden, np.float32),
            np.asarray(out_pad.block_hidden, np.float32), atol=1e-4)
        # retained caches must agree too (pre-pool masking keeps selection
        # independent of batch composition; rare fp-tie flips aside, the
        # overwhelming majority of retained positions must match)
        pos_eq = (np.asarray(out_pk.cache.pos)
                  == np.asarray(out_pad.cache.pos)).mean()
        assert pos_eq > 0.99, pos_eq


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 500), n=st.integers(1, 4))
def test_packed_refresh_property_random_ragged(seed, n):
    """Property form: any ragged batch, any block offsets, dense family."""
    cfg = reduced(ARCHS["llada-8b"])
    params = BB.init_params(cfg, jax.random.PRNGKey(1))
    ctx = T.ServeContext(block_size=8, retain=16, q_chunk=32, max_seq_len=64)
    rng = np.random.default_rng(seed)
    lens = [int(x) for x in rng.integers(9, 64, size=n)]
    bstarts = np.array([int(rng.integers(0, L - 8)) for L in lens], np.int32)
    tok_pad, valid_pad, flat, pos, seg, val, cu, sl = _ragged_stream(
        lens, 64, cfg.vocab_size, seed=seed, bucket=32)
    out_pad = BB.serve_refresh(
        params, cfg, jnp.asarray(tok_pad), jnp.asarray(bstarts), ctx,
        token_valid=jnp.asarray(valid_pad))
    out_pk = BB.serve_refresh_packed(
        params, cfg, jnp.asarray(flat), jnp.asarray(pos), jnp.asarray(seg),
        jnp.asarray(val), jnp.asarray(cu), jnp.asarray(sl),
        jnp.asarray(bstarts), ctx)
    np.testing.assert_allclose(
        np.asarray(out_pk.block_hidden, np.float32),
        np.asarray(out_pad.block_hidden, np.float32), atol=1e-4)


def test_varlen_score_chunking_invariance():
    """The jnp score fallback must chunk ANY stream length (sentinel-padded
    to whole chunks) without changing scores."""
    from repro.models.sparse_select import head_scores_varlen
    R, Sb, H, K, dh, T = 2, 4, 4, 2, 8, 40   # 40 % 16 != 0
    ks = jax.random.split(KEY, 2)
    q = jax.random.normal(ks[0], (R, Sb, H, dh))
    kf = jax.random.normal(ks[1], (T, K, dh))
    seg = np.repeat(np.arange(R, dtype=np.int32), [24, 16])
    a = head_scores_varlen(q, kf, jnp.asarray(seg), kernel_size=3,
                           s_chunk=16)
    b = head_scores_varlen(q, kf, jnp.asarray(seg), kernel_size=3,
                           s_chunk=4096)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_token_bucket_round_never_beats_pow2_oracle():
    """The packed bucket may never exceed the pow2 oracle bucket, even for
    non-pow2 token buckets (the CI waste gate's invariant)."""
    from repro.core.budgeting import pow2_bucket, token_bucket_round
    for bucket in (1, 3, 8, 24, 32, 100, 128):
        for n in range(1, 300):
            r = token_bucket_round(n, bucket)
            assert n <= r <= pow2_bucket(n), (n, bucket, r)


def test_selection_ignores_foreign_neighbours():
    """A request's retained KV set must not depend on what it is packed
    with: rows past seq_len in the per-request gather view belong to the
    NEXT request, and the score max-pool must not leak their relevance into
    valid boundary tokens (scores are masked to -inf pre-pool)."""
    from repro.models.sparse_select import select_and_pack
    B, Sb, K, G, S, dh = 1, 4, 2, 2, 24, 8
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, Sb, K * G, dh))
    kf = jax.random.normal(ks[1], (B, S, K, dh))
    vf = jax.random.normal(ks[2], (B, S, K, dh))
    valid = jnp.zeros((B, S), bool).at[:, :16].set(True)   # tokens ≥16 foreign
    excl = ~valid
    kw = dict(retain=8, kernel_size=3, mode="head", exclude=excl,
              token_valid=valid)
    p1 = select_and_pack(q, kf, vf, **kw)
    # replace the foreign tail with adversarially-huge keys: selection of the
    # valid region must be bit-identical
    kf2 = kf.at[:, 16:].set(100.0 * jnp.abs(kf[:, 16:]) + 50.0)
    p2 = select_and_pack(q, kf2, vf, **kw)
    assert np.array_equal(np.asarray(p1.pos), np.asarray(p2.pos))
    assert np.array_equal(np.asarray(p1.valid), np.asarray(p2.valid))


def test_windowed_stream_attention_matches_plain():
    """The windowed jnp fallback (KV window = q_chunk + 2L) must be exact:
    build a stream long enough that windows genuinely truncate."""
    cfg = reduced(ARCHS["llada-8b"])
    rng = np.random.default_rng(9)
    S_max, c = 24, 16
    lens, total = [], 0
    while total < 200:
        L = int(rng.integers(6, S_max + 1))
        lens.append(L)
        total += L
    tp = -(-total // c) * c
    seg = np.full(tp, PAD_SEG, np.int32)
    pos = np.zeros(tp, np.int32)
    val = np.zeros(tp, bool)
    off = 0
    for i, L in enumerate(lens):
        seg[off: off + L] = i
        pos[off: off + L] = np.arange(L)
        val[off: off + L] = True
        off += L
    H, K, dh = 4, 2, 16
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (1, tp, H, dh))
    k = jax.random.normal(kk, (1, tp, K, dh))
    v = jax.random.normal(kv, (1, tp, K, dh))
    serve = T.ServeContext(block_size=8, retain=8, q_chunk=c,
                           max_seq_len=S_max)
    assert c + 2 * S_max < tp   # windows actually truncate
    win = T._attend_packed_stream(
        q, k, v, jnp.asarray(pos)[None], jnp.asarray(seg)[None],
        jnp.asarray(val)[None], cfg, jnp.asarray(False), serve)
    ref_out = ref.varlen_attention(
        q[0], k[0], v[0], jnp.asarray(seg), jnp.asarray(pos),
        jnp.asarray(val))
    np.testing.assert_allclose(np.asarray(win)[0][val],
                               np.asarray(ref_out)[val], atol=2e-5)


# modality-frontend (vlm/audio) packed-vs-padded agreement lives in
# tests/test_frontend_packing.py — no family rejects the packed path anymore.


# ---------------------------------------------------------------------------
# SSM/hybrid: segment-reset varlen scan vs the padded oracle
# ---------------------------------------------------------------------------

SCAN_FAMS = ("mamba2-130m", "zamba2-7b")


@pytest.mark.parametrize("arch", SCAN_FAMS)
@pytest.mark.parametrize("use_kernel", [False, True])
def test_packed_refresh_matches_padded_scan_families(arch, use_kernel):
    """serve_refresh_packed for SSM/hybrid: block hidden AND the captured
    serving cache (recurrent state + conv history + hybrid packed KV
    positions) must reproduce the padded oracle on ragged batches."""
    cfg = reduced(ARCHS[arch])
    params = BB.init_params(cfg, KEY)
    ctx = T.ServeContext(block_size=8, retain=24, q_chunk=32, max_seq_len=96)
    ctx_pk = dataclasses.replace(ctx, use_flash_kernel=use_kernel)
    rng = np.random.default_rng(17)
    for trial in range(2):
        lens = [int(x) for x in rng.integers(12, 96, size=3)]
        bstarts = np.array([((L - 8) // 8) * 8 for L in lens], np.int32)
        tok_pad, valid_pad, flat, pos, seg, val, cu, sl = _ragged_stream(
            lens, 96, cfg.vocab_size, seed=trial)
        out_pad = BB.serve_refresh(
            params, cfg, jnp.asarray(tok_pad), jnp.asarray(bstarts), ctx,
            token_valid=jnp.asarray(valid_pad))
        out_pk = BB.serve_refresh_packed(
            params, cfg, jnp.asarray(flat), jnp.asarray(pos),
            jnp.asarray(seg), jnp.asarray(val), jnp.asarray(cu),
            jnp.asarray(sl), jnp.asarray(bstarts), ctx_pk)
        np.testing.assert_allclose(
            np.asarray(out_pk.block_hidden, np.float32),
            np.asarray(out_pad.block_hidden, np.float32), atol=1e-4)
        c_pk, c_pad = out_pk.cache, out_pad.cache
        st_pk = c_pk.state if arch == "mamba2-130m" else c_pk.ssm_state
        st_pad = c_pad.state if arch == "mamba2-130m" else c_pad.ssm_state
        np.testing.assert_allclose(np.asarray(st_pk), np.asarray(st_pad),
                                   atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(c_pk.conv, np.float32),
            np.asarray(c_pad.conv, np.float32), atol=1e-5)
        if arch == "zamba2-7b":
            pos_eq = (np.asarray(c_pk.kv.pos)
                      == np.asarray(c_pad.kv.pos)).mean()
            assert pos_eq > 0.99, pos_eq


@pytest.mark.parametrize("arch", SCAN_FAMS)
@pytest.mark.parametrize("use_kernel", [False, True])
def test_packed_reuse_matches_padded_scan_families(arch, use_kernel):
    """serve_reuse_packed for SSM/hybrid must reproduce the padded Reuse
    oracle on the same refreshed caches (hybrid exercises the causal flat
    cross-attention dispatch under use_kernel)."""
    cfg = reduced(ARCHS[arch])
    params = BB.init_params(cfg, KEY)
    ctx = T.ServeContext(block_size=8, retain=24, q_chunk=32, max_seq_len=96)
    ctx_pk = dataclasses.replace(ctx, use_flash_kernel=use_kernel)
    rng = np.random.default_rng(23)
    lens = [int(x) for x in rng.integers(16, 96, size=3)]
    bstarts = np.array([((L - 8) // 8) * 8 for L in lens], np.int32)
    cache, btok, bpos = _refresh_cache(cfg, params, ctx, lens, bstarts)
    h_pad = BB.serve_reuse(params, cfg, jnp.asarray(btok),
                           jnp.asarray(bpos), cache, ctx)
    h_pk = BB.serve_reuse_packed(
        params, cfg, jnp.asarray(btok.reshape(-1)),
        jnp.asarray(bpos.reshape(-1)), cache, ctx_pk)
    np.testing.assert_allclose(
        np.asarray(h_pk, np.float32).reshape(len(lens), 8, -1),
        np.asarray(h_pad, np.float32), atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 500), n=st.integers(1, 4))
def test_varlen_ssd_scan_segment_reset_property(seed, n):
    """cu_seqlens segment-reset property: the packed scan over a stream of n
    concatenated requests equals n independent per-request scans — outputs
    AND captured states at arbitrary rows (vs the sequential recurrence)."""
    from repro.models.ssm import varlen_ssd_scan
    H, P, N = 3, 4, 5
    rng = np.random.default_rng(seed)
    lens = [int(x) for x in rng.integers(3, 20, size=n)]
    T_real = sum(lens)
    tp = -(-T_real // 16) * 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    xh = jax.random.normal(ks[0], (tp, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (tp, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (tp, N))
    Cm = jax.random.normal(ks[4], (tp, N))
    reset = np.zeros(tp, bool)
    cu, off = [], 0
    for L in lens:
        reset[off] = True
        cu.append(off)
        off += L
    reset[off:] = True                       # bucket padding self-resets
    cap_off = [int(rng.integers(0, L)) for L in lens]
    cap_rows = np.array([c + o for c, o in zip(cu, cap_off)], np.int32)
    y, st = varlen_ssd_scan(xh, dt, A, Bm, Cm, jnp.asarray(reset),
                            jnp.asarray(cap_rows))
    # oracle: independent sequential recurrence per request
    for j, (c, L) in enumerate(zip(cu, lens)):
        state = np.zeros((H, P, N), np.float32)
        for t in range(c, c + L):
            a = np.exp(np.asarray(dt[t]) * np.asarray(A))
            state = state * a[:, None, None] + np.einsum(
                "h,n,hp->hpn", np.asarray(dt[t]), np.asarray(Bm[t]),
                np.asarray(xh[t]))
            yt = np.einsum("n,hpn->hp", np.asarray(Cm[t]), state)
            np.testing.assert_allclose(np.asarray(y[t]), yt, atol=2e-4)
            if t == cap_rows[j]:
                np.testing.assert_allclose(np.asarray(st[j]), state,
                                           atol=2e-4)


def test_ssm_segment_scan_kernel_matches_fallback():
    """The Pallas segment-scan kernel against the associative-scan fallback,
    invariant to the chunk tiling (the in-kernel capture accumulation must
    not depend on which chunk owns a capture row)."""
    from repro.kernels import ops
    from repro.models.ssm import varlen_ssd_scan
    H, P, N, tp = 4, 4, 6, 96
    rng = np.random.default_rng(2)
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    xh = jax.random.normal(ks[0], (tp, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (tp, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (tp, N))
    Cm = jax.random.normal(ks[4], (tp, N))
    reset = np.zeros(tp, bool)
    for off in (0, 17, 40, 77):
        reset[off] = True
    cap_rows = np.array([-1, 16, 39, 55, 95], np.int32)
    y_ref, st_ref = varlen_ssd_scan(xh, dt, A, Bm, Cm, jnp.asarray(reset),
                                    jnp.asarray(cap_rows))
    for chunk in (8, 16, 32, 48, 96):
        y, st = ops.ssm_segment_scan(xh, dt, A, Bm, Cm, jnp.asarray(reset),
                                     jnp.asarray(cap_rows), chunk=chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref, np.float32),
                                   atol=2e-4)
        np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                                   atol=2e-4)
        assert not np.asarray(st[0]).any()   # -1 capture row = zero state


# ---------------------------------------------------------------------------
# engine: the packed fast path never issues a padded refresh
# ---------------------------------------------------------------------------

def _serve_engine(serve, n=5, seed=0, arch="llada-8b", forbid_padded=False):
    cfg = reduced(ARCHS[arch])
    eng = Engine(cfg, serve, seed=seed)
    if forbid_padded:
        def _boom(chunk):
            raise AssertionError("padded [B, max_seq_len] refresh on the "
                                 "packed path")
        eng._run_refresh = _boom
    rng = np.random.default_rng(seed)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size - 1,
                                    int(rng.integers(8, 40))),
                       gen_len=16, arrival=0.0, rid=i)
            for i in range(n)]
    stats = eng.run()
    return eng, reqs, stats


def test_engine_packed_no_padded_refresh_call():
    eng, reqs, stats = _serve_engine(SERVE, forbid_padded=True)
    assert all(r.state == State.FINISHED for r in reqs)
    assert all((r.output_tokens() != eng.mask_id).all() for r in reqs)
    assert stats.padded_refresh_calls == 0
    assert stats.packed_refresh_calls > 0
    # executed tokens within one token-bucket of Σ total_len per dispatch
    assert stats.refresh_tokens_exec >= stats.refresh_tokens_real
    assert stats.refresh_tokens_exec < stats.refresh_tokens_real + \
        SERVE.token_bucket * stats.packed_refresh_calls


def test_engine_packed_padded_same_totals():
    _, r_pk, s_pk = _serve_engine(SERVE, seed=3)
    _, r_pd, s_pd = _serve_engine(
        dataclasses.replace(SERVE, varlen_pack=False), seed=3)
    assert s_pk.committed_tokens == s_pd.committed_tokens
    assert all(r.state == State.FINISHED for r in r_pk + r_pd)
    # the padded oracle pays strictly more executed tokens on ragged work
    assert s_pk.refresh_tokens_exec < s_pd.refresh_tokens_exec
    assert s_pk.refresh_tokens_real == s_pd.refresh_tokens_real


def test_engine_packed_flash_kernel_path():
    serve = dataclasses.replace(SERVE, use_flash_kernel=True)
    _, reqs, stats = _serve_engine(serve, n=3, forbid_padded=True)
    assert all(r.state == State.FINISHED for r in reqs)
    assert stats.packed_refresh_calls > 0


@pytest.mark.parametrize("arch", SCAN_FAMS)
def test_engine_scan_families_run_packed(arch):
    """Acceptance: under varlen_pack an SSM and a hybrid config serve
    Refresh AND Reuse with zero pow2-padded dispatches."""
    cfg = reduced(ARCHS[arch])
    eng = Engine(cfg, SERVE, seed=0)

    def _boom(*a, **k):
        raise AssertionError("pow2-padded dispatch on the packed path")

    eng._run_refresh = _boom
    eng._run_reuse = _boom
    eng._decode_fn = _boom
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size - 1,
                                    int(rng.integers(8, 40))),
                       gen_len=16, arrival=0.0, rid=i) for i in range(3)]
    stats = eng.run()
    assert all(r.state == State.FINISHED for r in reqs)
    assert all((r.output_tokens() != eng.mask_id).all() for r in reqs)
    assert stats.packed_refresh_calls > 0 and stats.padded_refresh_calls == 0
    assert stats.packed_reuse_calls > 0 and stats.padded_reuse_calls == 0


@pytest.mark.parametrize("arch", SCAN_FAMS)
def test_engine_scan_families_packed_padded_same_totals(arch):
    _, r_pk, s_pk = _serve_engine(SERVE, n=4, seed=3, arch=arch)
    _, r_pd, s_pd = _serve_engine(
        dataclasses.replace(SERVE, varlen_pack=False), n=4, seed=3, arch=arch)
    assert s_pk.committed_tokens == s_pd.committed_tokens
    assert all(r.state == State.FINISHED for r in r_pk + r_pd)
    assert s_pk.refresh_tokens_real == s_pd.refresh_tokens_real
    # the packed scan pays (at most) one token bucket over the real count;
    # the padded oracle pays the pow2 rectangle
    assert s_pk.refresh_tokens_exec < s_pd.refresh_tokens_exec
    assert s_pk.refresh_waste <= s_pd.refresh_waste
    assert s_pk.reuse_waste <= s_pd.reuse_waste


def test_engine_fused_refresh_single_dispatch():
    """The packed engine launches ONE fused refresh dispatch per iteration
    even when the refresh set spans several max_refresh_per_iter chunks.
    The request-level scheduler admits oversized refresh sets (the phase
    scheduler caps them at refresh_slots), so it is what exercises a
    multi-chunk layout."""
    serve = dataclasses.replace(SERVE, scheduler="request")
    eng, reqs, stats = _serve_engine(serve, n=6, seed=5, forbid_padded=True)
    assert all(r.state == State.FINISHED for r in reqs)
    n_refresh_iters = sum(1 for it in stats.iter_log if it["n_refresh"] > 0)
    assert stats.packed_refresh_calls == n_refresh_iters
    assert any(it["n_refresh"] > serve.max_refresh_per_iter
               for it in stats.iter_log), \
        "workload never exceeded one chunk — fusion untested"


def test_engine_zero_refresh_cap_serves_to_completion():
    """Acceptance: max_refresh_per_iter=0 (documented 0-means-unlimited)
    must serve to completion instead of deferring every Refresh forever."""
    serve0 = dataclasses.replace(SERVE, max_refresh_per_iter=0)
    eng, reqs, stats = _serve_engine(serve0, n=5, forbid_padded=True)
    assert all(r.state == State.FINISHED for r in reqs)
    assert stats.packed_refresh_calls > 0


# ---------------------------------------------------------------------------
# plan: packed layout + query-token invariant
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 10), budget=st.integers(64, 512),
       seed=st.integers(0, 99))
def test_packed_plan_layout_and_invariant(n, budget, seed):
    from repro.core.request import Request
    cfg = dataclasses.replace(SERVE, max_num_batched_tokens=budget)
    sched = PhaseMultiplexedScheduler(cfg)
    rng = np.random.default_rng(seed)
    for i in range(n):
        plen = int(rng.integers(4, 48))
        if plen + 16 + 8 > cfg.max_seq_len or plen + 16 > budget:
            plen = 8
        sched.submit(Request(rid=i, prompt=np.zeros(plen, np.int32),
                             gen_len=16, arrival=0.0, cfg=cfg, mask_id=255))
    for _ in range(3):
        plan = sched.plan(now=1e9)
        cu = plan.refresh_cu_seqlens()
        assert cu[0] == 0 and cu[-1] == plan.refresh_total_tokens
        assert np.all(np.diff(cu) > 0) or len(plan.refresh) == 0
        assert list(np.diff(cu)) == plan.refresh_token_counts
        # query-token invariant holds for the packed layout too
        assert plan.refresh_total_tokens <= plan.query_tokens <= budget
        for r in plan.refresh + plan.reuse:
            blk = r.block_tokens().copy()
            blk[:] = 1
            r.advance(blk, now=0.0)
            if r.state == State.FINISHED:
                sched.finish(r)


# ---------------------------------------------------------------------------
# budgeting: packed activation accounting buys KV slots
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# Reuse phase: packed stream vs the padded oracle (whole-iteration packing)
# ---------------------------------------------------------------------------

def _refresh_cache(cfg, params, ctx, lens, bstarts, seed=0):
    rng = np.random.default_rng(seed)
    R = len(lens)
    S = ctx.max_seq_len
    toks = np.zeros((R, S), np.int32)
    valid = np.zeros((R, S), bool)
    for j, L in enumerate(lens):
        toks[j, :L] = rng.integers(0, cfg.vocab_size - 1, L)
        valid[j, :L] = True
    out = BB.serve_refresh(params, cfg, jnp.asarray(toks),
                           jnp.asarray(bstarts), ctx,
                           token_valid=jnp.asarray(valid))
    btok = np.stack([toks[j, bstarts[j]: bstarts[j] + ctx.block_size]
                     for j in range(R)])
    bpos = np.stack([np.arange(b, b + ctx.block_size)
                     for b in bstarts]).astype(np.int32)
    return out.cache, btok, bpos


@pytest.mark.parametrize("arch", list(FAMS))
@pytest.mark.parametrize("use_kernel", [False, True])
def test_packed_reuse_matches_padded(arch, use_kernel):
    """serve_reuse_packed must reproduce the padded Reuse oracle on the same
    gathered caches — jnp fallback bit-comparable, cross kernel to fp
    tolerance (gemma2 exercises softcap + alternating local windows)."""
    cfg = reduced(ARCHS[arch], **FAMS[arch])
    params = BB.init_params(cfg, KEY)
    ctx = T.ServeContext(block_size=8, retain=24, q_chunk=32, max_seq_len=96)
    ctx_pk = dataclasses.replace(ctx, use_flash_kernel=use_kernel)
    rng = np.random.default_rng(13)
    for trial in range(2):
        lens = [int(x) for x in rng.integers(16, 96, size=3)]
        bstarts = np.array([((L - 8) // 8) * 8 for L in lens], np.int32)
        cache, btok, bpos = _refresh_cache(cfg, params, ctx, lens, bstarts,
                                           seed=trial)
        h_pad = BB.serve_reuse(params, cfg, jnp.asarray(btok),
                               jnp.asarray(bpos), cache, ctx)
        h_pk = BB.serve_reuse_packed(
            params, cfg, jnp.asarray(btok.reshape(-1)),
            jnp.asarray(bpos.reshape(-1)), cache, ctx_pk)
        np.testing.assert_allclose(
            np.asarray(h_pk, np.float32).reshape(len(lens), 8, -1),
            np.asarray(h_pad, np.float32), atol=2e-4)


def test_cross_kernel_matches_masked_reference():
    """The cross-attention varlen kernel (packed queries vs per-segment KV,
    per-head KV positions/validity) against a full-mask jnp reference."""
    rng = np.random.default_rng(5)
    R, Sb, Cr = 4, 8, 16
    H, K, dh = 4, 2, 16
    G = H // K
    Tq, Tkv = R * Sb, R * (Cr + Sb)
    q_seg = np.repeat(np.arange(R, dtype=np.int32), Sb)
    kv_seg = np.repeat(np.arange(R, dtype=np.int32), Cr + Sb)
    # engine-coherent geometry: each request's block queries are contiguous
    # positions, its cache positions precede the block, and the live-block
    # KV tail mirrors the query positions (so no query row is ever fully
    # masked, even under a sliding window — the engine invariant)
    bstarts = rng.integers(0, 48, R).astype(np.int32)
    q_pos = np.concatenate([b + np.arange(Sb, dtype=np.int32)
                            for b in bstarts])
    kv_pos = np.zeros((K, Tkv), np.int32)
    kv_valid = rng.random((K, Tkv)) > 0.25
    kv_valid = kv_valid.reshape(K, R, Cr + Sb)
    kv_pos = kv_pos.reshape(K, R, Cr + Sb)
    for j, b in enumerate(bstarts):
        kv_pos[:, j, :Cr] = rng.integers(0, max(1, b) + Sb, (K, Cr))
        kv_pos[:, j, Cr:] = b + np.arange(Sb)
    kv_valid[:, :, Cr:] = True
    kv_pos = kv_pos.reshape(K, Tkv)
    kv_valid = kv_valid.reshape(K, Tkv)
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (Tq, H, dh))
    k = jax.random.normal(ks[1], (K, Tkv, dh))
    v = jax.random.normal(ks[2], (K, Tkv, dh))
    for softcap, window, is_local in [(0.0, 0, False), (20.0, 8, True)]:
        out = ops.flash_varlen_cross_attention(
            q, k, v, q_seg=jnp.asarray(q_seg), q_pos=jnp.asarray(q_pos),
            kv_seg=jnp.asarray(kv_seg), kv_pos=jnp.asarray(kv_pos),
            kv_valid=jnp.asarray(kv_valid), window=window,
            is_local=is_local, softcap=softcap, q_tile=8, kv_tile=16)
        # reference: per-head full [Tq, Tkv] masked softmax
        qg = np.asarray(q).reshape(Tq, K, G, dh)
        z = np.einsum("tkgd,ksd->kgts", qg, np.asarray(k)) * dh ** -0.5
        if softcap:
            z = softcap * np.tanh(z / softcap)
        ok = (q_seg[:, None] == kv_seg[None, :])[None] & kv_valid[:, None, :]
        if window:
            dist = np.abs(q_pos[None, :, None] - kv_pos[:, None, :])
            ok = ok & np.where(is_local, dist <= window, True)
        z = np.where(ok[:, None], z, -1e30)
        p = jax.nn.softmax(jnp.asarray(z), axis=-1)
        ref_out = np.einsum("kgts,ksd->tkgd", np.asarray(p), np.asarray(v))
        np.testing.assert_allclose(
            np.asarray(out), ref_out.reshape(Tq, H, dh), atol=2e-5)


# ---------------------------------------------------------------------------
# logit stage: packed decode vs the padded oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["llada-8b", "gemma2-27b"])
@pytest.mark.parametrize("mode", ["chunked", "fused", "monolithic"])
def test_packed_decode_matches_padded(arch, mode):
    """decode_tokens_packed over a token-bucketed stream with a validity
    mask: exact ids and confidence-to-tolerance agreement with the oracle on
    the real rows, zeros on the padding rows (gemma2 = tied embeddings +
    final softcap)."""
    from repro.models import lm_head as LM
    cfg = reduced(ARCHS[arch])
    params = BB.init_params(cfg, KEY)
    rng = np.random.default_rng(4)
    for trial in range(3):
        N = int(rng.integers(3, 80))
        Nx = N + int(rng.integers(0, 40))
        h = jax.random.normal(jax.random.PRNGKey(trial), (Nx, cfg.d_model),
                              jnp.dtype(cfg.dtype))
        valid = jnp.arange(Nx) < N
        ids_p, conf_p = LM.decode_tokens_packed(
            params["embed"], cfg, h, valid, max_num_logits=16, mode=mode,
            vocab_tile=64)
        ids_o, conf_o = LM.decode_tokens(
            params["embed"], cfg, h[:N], max_num_logits=16, mode=mode,
            vocab_tile=64)
        assert np.array_equal(np.asarray(ids_p[:N]), np.asarray(ids_o))
        np.testing.assert_allclose(np.asarray(conf_p[:N]),
                                   np.asarray(conf_o), atol=2e-5)
        assert not np.asarray(ids_p[N:]).any()
        assert not np.asarray(conf_p[N:]).any()


# ---------------------------------------------------------------------------
# engine: the whole-iteration packed pipeline
# ---------------------------------------------------------------------------

def test_engine_packed_no_padded_reuse_or_decode():
    """Under varlen_pack no stage may fall back to a pow2 dispatch."""
    cfg = reduced(ARCHS["llada-8b"])
    eng = Engine(cfg, SERVE, seed=0)

    def _boom(*a, **k):
        raise AssertionError("pow2-padded dispatch on the packed path")

    eng._run_refresh = _boom
    eng._run_reuse = _boom
    eng._decode_fn = _boom
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size - 1,
                                    int(rng.integers(8, 40))),
                       gen_len=16, arrival=0.0, rid=i) for i in range(5)]
    stats = eng.run()
    assert all(r.state == State.FINISHED for r in reqs)
    assert stats.packed_reuse_calls > 0 and stats.padded_reuse_calls == 0
    assert stats.logit_tokens_real > 0


def test_engine_whole_iteration_packed_accounting():
    """Acceptance: one full modeled-clock serve run reports per-iteration
    ``reuse_tokens_exec == R·block_size`` rounded only to the token bucket
    (exact below one bucket — never pow2) and ``logit_tokens_exec`` below
    the pow2 row bucket whenever the plan is ragged."""
    from repro.core.budgeting import pow2_bucket
    serve = dataclasses.replace(SERVE, token_bucket=32)
    cfg = reduced(ARCHS["llada-8b"])
    eng = Engine(cfg, serve, seed=7, clock="modeled")
    rng = np.random.default_rng(7)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size - 1,
                                    int(rng.integers(8, 60))),
                       gen_len=16, arrival=0.0, rid=i) for i in range(7)]
    stats = eng.run()
    assert all(r.state == State.FINISHED for r in reqs)
    Sb = serve.block_size
    rb = serve.token_bucket // Sb
    saw_ragged_logit = False
    for it in stats.iter_log:
        n = it["n_reuse"]
        if n:
            rp = n if n <= rb else -(-n // rb) * rb
            assert it["reuse_tokens_exec"] == rp * Sb, it
        nr = it["logit_tokens_real"]
        if nr:
            tb = serve.token_bucket
            expect = nr if nr <= tb else -(-nr // tb) * tb
            assert it["logit_tokens_exec"] == expect, it
            assert expect <= pow2_bucket(nr, lo=Sb), it
            if expect < pow2_bucket(nr, lo=Sb):
                # ragged plan: packed exec beats the pow2 row bucket
                saw_ragged_logit = True
    assert saw_ragged_logit
    assert stats.reuse_tokens_exec >= stats.reuse_tokens_real
    assert stats.logit_tokens_exec >= stats.logit_tokens_real


def test_engine_packed_waste_never_worse_than_padded():
    _, r_pk, s_pk = _serve_engine(SERVE, n=6, seed=11)
    _, r_pd, s_pd = _serve_engine(
        dataclasses.replace(SERVE, varlen_pack=False), n=6, seed=11)
    assert s_pk.committed_tokens == s_pd.committed_tokens
    assert s_pk.refresh_waste <= s_pd.refresh_waste
    assert s_pk.reuse_waste <= s_pd.reuse_waste
    assert s_pk.logit_waste <= s_pd.logit_waste
    assert s_pk.reuse_tokens_real == s_pd.reuse_tokens_real
    assert s_pk.logit_tokens_real == s_pd.logit_tokens_real


# ---------------------------------------------------------------------------
# plan: whole-iteration packed layout partitions the stream exactly
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 12), budget=st.integers(64, 512),
       cap=st.integers(1, 4), seed=st.integers(0, 99))
def test_whole_iteration_layout_partitions_stream(n, budget, cap, seed):
    """Property: for random plans, every stage's cu_seqlens partition its
    stream with no overlap and no gap, refresh chunks tile the plan-level
    stream, reuse segments are exactly block_size, and logit_tokens counts
    one block per scheduled request."""
    from repro.core.request import Request
    cfg = dataclasses.replace(SERVE, max_num_batched_tokens=budget)
    sched = PhaseMultiplexedScheduler(cfg)
    rng = np.random.default_rng(seed)
    for i in range(n):
        plen = int(rng.integers(4, 48))
        if plen + 16 + 8 > cfg.max_seq_len or plen + 16 > budget:
            plen = 8
        sched.submit(Request(rid=i, prompt=np.zeros(plen, np.int32),
                             gen_len=16, arrival=0.0, cfg=cfg, mask_id=255))
    for _ in range(4):
        plan = sched.plan(now=1e9)
        layout = plan.packed_layout(cap)
        # refresh chunks tile the plan-level stream
        off = 0
        plan_cu = plan.refresh_cu_seqlens()
        covered = []
        for seg in layout.refresh_chunks:
            cu = seg.cu_seqlens
            assert cu[0] == 0
            assert np.all(np.diff(cu) > 0)
            assert seg.token_counts == [r.total_len for r in seg.requests]
            for j in range(len(seg.requests)):
                covered.append((off + int(cu[j]), off + int(cu[j + 1])))
            off += seg.total_tokens
        assert off == plan.refresh_total_tokens == plan_cu[-1]
        # segments are contiguous, non-overlapping, gap-free
        for (a0, a1), (b0, b1) in zip(covered, covered[1:]):
            assert a1 == b0 and a0 < a1
        if layout.reuse:
            cu = layout.reuse.cu_seqlens
            assert list(np.diff(cu)) == [cfg.block_size] * len(plan.reuse)
        assert layout.logit_tokens == \
            (len(plan.refresh) + len(plan.reuse)) * cfg.block_size
        for r in plan.refresh + plan.reuse:
            blk = r.block_tokens().copy()
            blk[:] = 1
            r.advance(blk, now=0.0)
            if r.state == State.FINISHED:
                sched.finish(r)


def test_budgeting_packed_tokens_buy_slots():
    from repro.configs import get_config
    from repro.core.budgeting import max_exec_tokens, plan_memory
    cfg = get_config("llada-8b")
    base = ServeConfig(max_num_batched_tokens=4000, max_num_logits=2048,
                       max_seq_len=2048, max_slots=256, max_refresh_per_iter=4,
                       logit_mode="chunked")
    packed = dataclasses.replace(base, varlen_pack=True)
    assert max_exec_tokens(packed, cfg) < max_exec_tokens(base, cfg)
    # every family is billed by packed tokens now: the scan families
    # (segment-reset varlen scan) AND the modality-frontend archs
    # (frontend-prefix segments) — no padded reservation survives under
    # varlen_pack
    from repro.configs import get_config as _gc
    ssm_cfg = _gc("mamba2-130m")
    assert max_exec_tokens(packed, ssm_cfg) < max_exec_tokens(base, ssm_cfg)
    vlm_cfg = _gc("internvl2-76b")
    assert max_exec_tokens(packed, vlm_cfg) < max_exec_tokens(base, vlm_cfg)
    p_pad = plan_memory(cfg, base, 24 << 30)
    p_pk = plan_memory(cfg, packed, 24 << 30)
    assert p_pk.activation_bytes < p_pad.activation_bytes
    assert p_pk.max_slots >= p_pad.max_slots
    assert p_pk.kv_pool_bytes > p_pad.kv_pool_bytes


def test_budgeting_bills_reuse_and_logit_by_packed_tokens():
    """plan_memory's per-stage accounting mirrors the engine's real
    execution: Reuse and the logit stage are billed token-bucketed under
    varlen_pack, pow2-bucketed otherwise."""
    from repro.configs import get_config
    from repro.core.budgeting import (logit_exec_tokens, pow2_bucket,
                                      reuse_exec_tokens)
    cfg = get_config("llada-8b")
    base = ServeConfig(max_num_batched_tokens=4000, max_num_logits=2048,
                       max_seq_len=2048, max_slots=48,
                       logit_mode="monolithic")
    packed = dataclasses.replace(base, varlen_pack=True)
    # reuse: pow2(min(slots, budget // Sb)) vs token-bucket multiples
    # (48 slots: pow2 pays 64 blocks, the packed stream exactly 48)
    assert reuse_exec_tokens(base, cfg) == \
        pow2_bucket(base.max_slots) * base.block_size
    assert reuse_exec_tokens(packed, cfg) < reuse_exec_tokens(base, cfg)
    assert reuse_exec_tokens(packed, cfg) % packed.token_bucket == 0
    # every family packs its Reuse stream now — SSM and the frontend archs
    # included (the Reuse stream is text-only for vlm/audio too)
    ssm = get_config("mamba2-130m")
    assert reuse_exec_tokens(packed, ssm) < reuse_exec_tokens(base, ssm)
    vlm = get_config("internvl2-76b")
    assert reuse_exec_tokens(packed, vlm) < reuse_exec_tokens(base, vlm)
    # logit stage: ragged N → token-bucket rounding beats the pow2 bucket
    # (and the logit head packs for every family, SSM included)
    n = 2500
    assert logit_exec_tokens(base, n) == pow2_bucket(n, lo=base.block_size)
    assert logit_exec_tokens(packed, n) < logit_exec_tokens(base, n)
    from repro.core.budgeting import logit_activation_bytes
    assert logit_activation_bytes(cfg, packed, n) < \
        logit_activation_bytes(cfg, base, n)